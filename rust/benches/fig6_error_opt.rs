//! Fig 6 — effectiveness of the error-aware optimisation techniques:
//! retrieval precision vs process corner for {naive, naive+detect,
//! error-aware remap, remap+detect}, with the paper's headline "+24.6%
//! precision from bitwise remapping" checked at the stressed corner.

mod common;

use dirc_rag::bench::Table;
use dirc_rag::data::dataset_by_name;
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::dirc::variation::VariationModel;
use dirc_rag::dirc::RemapStrategy;
use dirc_rag::eval::evaluate;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::Prune;

fn main() {
    let spec = dataset_by_name("scifact").unwrap();
    let nq = common::query_cap(120);
    let ds = common::generate(&spec);
    let db = quantize(&ds.docs, ds.n_docs, ds.dim, QuantScheme::Int8);

    // Clean reference.
    let clean_cfg = ChipConfig { map_points: 150, ..ChipConfig::paper_default(spec.dim, Metric::Cosine) };
    let clean_chip = DircChip::build(clean_cfg, &db);
    let queries: Vec<Vec<i8>> = (0..nq)
        .map(|qi| quantize(ds.query(qi), 1, ds.dim, QuantScheme::Int8).values)
        .collect();
    let oracle = QueryPlan::topk(5).prune(Prune::None).build().unwrap();
    let clean =
        evaluate(nq, &ds.qrels[..nq], |qi| clean_chip.clean_execute(&queries[qi], &oracle));

    let corners = [1.0, 2.0, 2.5, 3.0];
    let configs: [(&str, RemapStrategy, bool); 4] = [
        ("naive", RemapStrategy::Interleaved, false),
        ("naive+detect", RemapStrategy::Interleaved, true),
        ("remap", RemapStrategy::ErrorAware, false),
        ("remap+detect", RemapStrategy::ErrorAware, true),
    ];

    let mut t = Table::new(&["corner", "config", "P@1", "P@3", "P@5", "vs naive P@1"]);
    let mut stressed: Vec<(String, f64)> = Vec::new();

    for &corner in &corners {
        let mut naive_p1 = None;
        for (name, remap, detect) in configs {
            let cfg = ChipConfig {
                remap,
                detect,
                variation: VariationModel { corner, ..VariationModel::default() },
                map_points: 150,
                ..ChipConfig::paper_default(spec.dim, Metric::Cosine)
            };
            let chip = DircChip::build(cfg, &db);
            // Seed 17: the nonce stream the pre-plan sweep drew from
            // Pcg::new(17), one nonce per query in order.
            let outs =
                chip.execute_batch(&queries, &QueryPlan::topk(5).seed(17).build().unwrap());
            let rep = evaluate(nq, &ds.qrels[..nq], |qi| outs[qi].topk.clone());
            let base = *naive_p1.get_or_insert(rep.p_at_1);
            t.row(&[
                format!("{corner:.1}x"),
                name.to_string(),
                format!("{:.4}", rep.p_at_1),
                format!("{:.4}", rep.p_at_3),
                format!("{:.4}", rep.p_at_5),
                format!("{:+.1}%", (rep.p_at_1 / base.max(1e-9) - 1.0) * 100.0),
            ]);
            if corner == 2.5 {
                stressed.push((name.to_string(), rep.p_at_1));
            }
        }
    }

    println!("\n=== Fig 6: error-aware optimisation vs process corner ===");
    println!(
        "clean reference: P@1 {:.4}  P@3 {:.4}  P@5 {:.4}  ({nq} queries)",
        clean.p_at_1, clean.p_at_3, clean.p_at_5
    );
    t.print();

    // Headline check at the stressed corner: remap uplift over naive in
    // the paper's ballpark (+24.6%); remap+detect recovers ~the clean
    // precision.
    let get = |n: &str| stressed.iter().find(|(s, _)| s == n).unwrap().1;
    let uplift = (get("remap") / get("naive").max(1e-9) - 1.0) * 100.0;
    let full = get("remap+detect");
    println!(
        "\nremap uplift at 2.5x corner: {uplift:+.1}% (paper: +24.6%); \
         remap+detect P@1 {full:.4} vs clean {:.4}",
        clean.p_at_1
    );
    assert!(uplift > 10.0, "remap must deliver a double-digit uplift");
    assert!(
        full >= clean.p_at_1 * 0.93,
        "remap+detect must recover near-clean precision"
    );
}
