#![allow(dead_code)]

//! Shared helpers for the paper-reproduction benches.

use dirc_rag::data::DatasetSpec;
use dirc_rag::data::SynthDataset;

/// Query cap per dataset: full run by default, trimmed under
/// `DIRC_BENCH_FAST=1` (CI smoke).
pub fn query_cap(spec_queries: usize) -> usize {
    if std::env::var("DIRC_BENCH_FAST").ok().as_deref() == Some("1") {
        spec_queries.min(40)
    } else {
        spec_queries.min(250)
    }
}

/// Monte-Carlo points for error-map extraction in benches.
pub fn map_points() -> usize {
    if std::env::var("DIRC_BENCH_FAST").ok().as_deref() == Some("1") {
        120
    } else {
        1000
    }
}

/// Generate a registered dataset.
pub fn generate(spec: &DatasetSpec) -> SynthDataset {
    SynthDataset::generate(spec.n_docs, spec.n_queries, spec.dim, &spec.params)
}
