//! Two-stage cluster-pruned retrieval on the synthetic 4 MB corpus:
//! exhaustive vs centroid-prefiltered queries on the same chip, with the
//! modeled per-query cycle/energy accounting and the measured recall
//! side by side. Emits the `BENCH_4.json` trajectory artifact (override
//! the path with `DIRC_BENCH_OUT`).
//!
//! ```bash
//! cargo bench --bench cluster_pruning
//! ```
//!
//! Gates (deterministic — all modeled metrics come from the simulator):
//!
//! * `nprobe = n_clusters` is bit-identical to the exhaustive path;
//! * at the default `nprobe`, summed per-query sense work drops >= 3x;
//! * pruned recall@10 against the exhaustive ranking stays high (the
//!   hard 2% P@k gate lives in `tests/precision_regression.rs`).

use std::sync::Arc;

use dirc_rag::bench::{fmt_duration, Bench, Table};
use dirc_rag::data::{SynthDataset, SynthParams};
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::eval::precision_at_k;
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::{QueryPlan, StatsDetail};
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::Prune;
use dirc_rag::util::json::Json;
use dirc_rag::util::pool::ThreadPool;

const N_CLUSTERS: usize = 128;

/// Modeled + measured census of one evaluation sweep.
#[derive(Default, Clone)]
struct Sweep {
    work_cycles: f64,
    cycles: f64,
    energy_j: f64,
    latency_s: f64,
    macros_sensed: f64,
    p1: f64,
    p5: f64,
    p10: f64,
    topk: Vec<Vec<u64>>,
}

/// The evaluation plan at a pruning policy: seed 17 reproduces the
/// nonce stream the pre-plan sweep drew from `Pcg::new(17)`, so both
/// arms (and any rerun) sense identical flips.
fn sweep_plan(prune: Prune) -> QueryPlan {
    QueryPlan::topk(10).prune(prune).seed(17).build().expect("sweep plan")
}

fn sweep(chip: &DircChip, ds: &SynthDataset, queries: &[Vec<i8>], prune: Prune) -> Sweep {
    let mut s = Sweep::default();
    let outs = chip.execute_batch(queries, &sweep_plan(prune));
    for (qi, out) in outs.iter().enumerate() {
        let (ranked, stats) = (&out.topk, &out.stats);
        s.work_cycles += stats.work_cycles as f64;
        s.cycles += stats.cycles as f64;
        s.energy_j += stats.energy_j;
        s.latency_s += stats.latency_s;
        s.macros_sensed += stats.macros_sensed as f64;
        s.p1 += precision_at_k(ranked, &ds.qrels[qi], 1);
        s.p5 += precision_at_k(ranked, &ds.qrels[qi], 5);
        s.p10 += precision_at_k(ranked, &ds.qrels[qi], 10);
        s.topk.push(ranked.iter().map(|d| d.doc_id).collect());
    }
    let n_queries = queries.len();
    let n = n_queries as f64;
    s.work_cycles /= n;
    s.cycles /= n;
    s.energy_j /= n;
    s.latency_s /= n;
    s.macros_sensed /= n;
    s.p1 /= n;
    s.p5 /= n;
    s.p10 /= n;
    s
}

fn sweep_json(s: &Sweep) -> Json {
    Json::obj(vec![
        ("work_cycles_per_query", Json::num(s.work_cycles)),
        ("latency_cycles_per_query", Json::num(s.cycles)),
        ("energy_uj_per_query", Json::num(s.energy_j * 1e6)),
        ("latency_us_per_query", Json::num(s.latency_s * 1e6)),
        ("macros_sensed_avg", Json::num(s.macros_sensed)),
        ("p_at_1", Json::num(s.p1)),
        ("p_at_5", Json::num(s.p5)),
        ("p_at_10", Json::num(s.p10)),
    ])
}

fn main() {
    let fast = std::env::var("DIRC_BENCH_FAST").ok().as_deref() == Some("1");
    // Full 4 MB chip: 8192 docs x 512 dims INT8 on 16 cores, with real
    // topic structure so measured recall means something.
    let (n, dim) = (8192usize, 512usize);
    let n_queries = if fast { 24 } else { 64 };
    let params = SynthParams {
        topics: 32,
        doc_noise: 0.6,
        rels_per_query: 1,
        extra_rel_range: 1,
        query_noise: 0.5,
        confuse: 0.6,
        aniso: 1.0,
        seed: 4141,
    };
    eprintln!("generating {n} x {dim} corpus + building clustered chip...");
    let ds = SynthDataset::generate(n, n_queries, dim, &params);
    let db = quantize(&ds.docs, n, dim, QuantScheme::Int8);
    let cfg = ChipConfig {
        map_points: if fast { 40 } else { 80 },
        cluster: ClusterPolicy { n_clusters: N_CLUSTERS, nprobe: 4, kmeans_iters: 8 },
        ..ChipConfig::paper_default(dim, Metric::Cosine)
    };
    let chip = Arc::new(DircChip::build(cfg, &db));
    assert_eq!(db.stored_bytes(), 4 << 20, "corpus must be exactly 4 MB INT8");

    // The query stream, quantised once and shared by every pass below.
    let queries: Vec<Vec<i8>> = (0..n_queries)
        .map(|qi| quantize(ds.query(qi), 1, dim, QuantScheme::Int8).values)
        .collect();

    // Correctness gate before any numbers: probing every centroid must
    // reproduce the exhaustive path bit-for-bit.
    {
        let base = QueryPlan::topk(10).seed(5).build().unwrap();
        let full = chip.execute(&queries[0], &base.with_prune(Prune::None).unwrap());
        let all =
            chip.execute(&queries[0], &base.with_prune(Prune::Probe(N_CLUSTERS)).unwrap());
        assert_eq!(full.topk, all.topk, "nprobe = n_clusters diverged from exhaustive");
        assert_eq!(full.stats.cycles, all.stats.cycles);
        assert_eq!(full.stats.energy_j.to_bits(), all.stats.energy_j.to_bits());
    }

    let exhaustive = sweep(&chip, &ds, &queries, Prune::None);
    let pruned = sweep(&chip, &ds, &queries, Prune::Default);

    // Recall of the pruned run against the exhaustive ranking (same rng
    // stream -> identical sensing flips; the difference is purely the
    // candidate restriction).
    let recall10: f64 = exhaustive
        .topk
        .iter()
        .zip(&pruned.topk)
        .map(|(f, p)| f.iter().filter(|id| p.contains(id)).count() as f64 / f.len() as f64)
        .sum::<f64>()
        / exhaustive.topk.len() as f64;

    let work_ratio = exhaustive.work_cycles / pruned.work_cycles;
    let energy_ratio = exhaustive.energy_j / pruned.energy_j;
    let latency_ratio = exhaustive.latency_s / pruned.latency_s;

    let mut t = Table::new(&["path", "work cyc/q", "energy µJ/q", "latency µs/q", "P@10"]);
    t.row(&[
        "exhaustive".into(),
        format!("{:.0}", exhaustive.work_cycles),
        format!("{:.3}", exhaustive.energy_j * 1e6),
        format!("{:.2}", exhaustive.latency_s * 1e6),
        format!("{:.4}", exhaustive.p10),
    ]);
    t.row(&[
        format!("pruned ({N_CLUSTERS}c/np4)"),
        format!("{:.0}", pruned.work_cycles),
        format!("{:.3}", pruned.energy_j * 1e6),
        format!("{:.2}", pruned.latency_s * 1e6),
        format!("{:.4}", pruned.p10),
    ]);
    println!("\n=== cluster_pruning: exhaustive vs two-stage on the 4 MB corpus ===");
    t.print();
    println!(
        "sense-work saving {work_ratio:.2}x, energy saving {energy_ratio:.2}x, \
         latency ratio {latency_ratio:.2}x, macros sensed {:.1}/16, \
         recall@10 vs exhaustive {recall10:.4}",
        pruned.macros_sensed
    );

    // Host-side throughput: the skipped (query, core) jobs never reach
    // the pool, so pruning also buys wall-clock on the simulator. The
    // timing plans run at StatsDetail::Counters — results are identical
    // (pinned above), the cycle/energy census is pure overhead here.
    let mut b = Bench::new();
    let pool = Arc::new(ThreadPool::new(4));
    let host_plan = |prune: Prune| {
        QueryPlan::topk(10)
            .prune(prune)
            .seed(9)
            .pool(Arc::clone(&pool))
            .detail(StatsDetail::Counters)
            .build()
            .expect("host timing plan")
    };
    let full_plan = host_plan(Prune::None);
    let host_full = b
        .run("batch exhaustive (pool of 4)", || {
            chip.execute_batch(&queries, &full_plan).len()
        })
        .summary
        .median;
    let pruned_plan = host_plan(Prune::Default);
    let host_pruned = b
        .run("batch pruned (pool of 4)", || {
            chip.execute_batch(&queries, &pruned_plan).len()
        })
        .summary
        .median;
    println!(
        "host wall-clock per batch: exhaustive {} vs pruned {} ({:.2}x)",
        fmt_duration(host_full),
        fmt_duration(host_pruned),
        host_full / host_pruned
    );

    // The acceptance gates (all modeled -> deterministic, not flaky).
    assert!(
        work_ratio >= 3.0,
        "default-nprobe pruning must drop modeled sense work >= 3x, got {work_ratio:.2}x"
    );
    assert!(
        recall10 >= 0.8,
        "pruned recall@10 vs exhaustive collapsed: {recall10:.3}"
    );

    // Default to the workspace root (cargo runs bench binaries with the
    // package dir — rust/ — as CWD, so a bare relative path would land
    // the artifact in the wrong place and break the CI upload).
    let out = std::env::var("DIRC_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_4.json").into());
    let json = Json::obj(vec![
        ("bench", Json::str("cluster_pruning")),
        (
            "corpus",
            Json::obj(vec![
                ("docs", Json::num(n as f64)),
                ("dim", Json::num(dim as f64)),
                ("stored_mb", Json::num(db.stored_bytes() as f64 / (1 << 20) as f64)),
                ("queries", Json::num(n_queries as f64)),
            ]),
        ),
        (
            "config",
            Json::obj(vec![
                ("n_clusters", Json::num(N_CLUSTERS as f64)),
                ("nprobe", Json::num(4.0)),
                ("cores", Json::num(16.0)),
            ]),
        ),
        // The sweep's QueryPlan, recorded so the trajectory artifact is
        // self-describing: what k / prune / exec / rng produced it.
        ("plan", {
            let plan = sweep_plan(Prune::Default);
            Json::obj(vec![
                ("k", Json::num(plan.k() as f64)),
                ("prune", Json::str(format!("{:?}", plan.prune()))),
                ("exec", Json::str(plan.exec().name())),
                ("rng", Json::str(format!("{:?}", plan.rng()))),
                ("detail", Json::str(format!("{:?}", plan.detail()))),
            ])
        }),
        ("exhaustive", sweep_json(&exhaustive)),
        ("pruned", sweep_json(&pruned)),
        (
            "savings",
            Json::obj(vec![
                ("work_ratio", Json::num(work_ratio)),
                ("energy_ratio", Json::num(energy_ratio)),
                ("latency_ratio", Json::num(latency_ratio)),
                ("recall_at_10_vs_exhaustive", Json::num(recall10)),
            ]),
        ),
    ]);
    std::fs::write(&out, json.to_string_pretty()).expect("write bench artifact");
    println!("wrote {out}");

    b.report("cluster_pruning");
}
