//! Parallel sharded query execution: thread-count scaling of the chip's
//! per-core job fan-out, plus the queries × cores batch matrix on the
//! shared thread pool. Proves the parallel path buys near-linear speedup
//! while staying bit-identical to the serial walk.
//!
//! ```bash
//! cargo bench --bench parallel_scaling
//! ```

use std::sync::Arc;

use dirc_rag::bench::{fmt_duration, Bench, Table};
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::util::pool::ThreadPool;
use dirc_rag::util::rng::Pcg;

fn main() {
    // Full 4 MB chip: 8192 docs x 512 dims INT8 on 16 cores.
    let (n, dim) = (8192usize, 512usize);
    let mut rng = Pcg::new(1);
    let fp: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.05).collect();
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let cfg = ChipConfig { map_points: 80, ..ChipConfig::paper_default(dim, Metric::Mips) };
    let chip = Arc::new(DircChip::build(cfg, &db));
    let q: Vec<i8> = (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect();

    // Correctness first: the parallel path must be bit-identical to the
    // serial path before any of the timings below mean anything.
    {
        let mut r1 = Pcg::new(9);
        let mut r2 = Pcg::new(9);
        let (top_s, stats_s) = chip.query(&q, 10, &mut r1);
        let (top_p, stats_p) = chip.query_on(&q, 10, &mut r2, 4);
        assert_eq!(top_s, top_p, "parallel ranking diverged from serial");
        assert_eq!(stats_s.cycles, stats_p.cycles);
        assert_eq!(stats_s.sense, stats_p.sense);
    }

    let mut b = Bench::new();
    let thread_counts = [1usize, 2, 4, 8, 16];
    let mut medians: Vec<(usize, f64)> = Vec::new();
    for &threads in &thread_counts {
        let r = b.run(&format!("single query (16 cores), {threads} threads"), || {
            let mut r = Pcg::new(2);
            chip.query_on(&q, 10, &mut r, threads).1.cycles
        });
        medians.push((threads, r.summary.median));
    }

    // Batch throughput: 32 queries serial vs the queries x cores matrix.
    let mut qrng = Pcg::new(3);
    let queries: Vec<Vec<i8>> = (0..32)
        .map(|_| (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let serial_batch = b
        .run("batch of 32 queries, serial loop", || {
            let mut r = Pcg::new(4);
            queries
                .iter()
                .map(|q| chip.query(q, 10, &mut r).1.cycles)
                .sum::<u64>()
        })
        .summary
        .median;
    let pool = ThreadPool::new(4);
    let matrix_batch = b
        .run("batch of 32 queries, 4-worker pool (queries x cores matrix)", || {
            let mut r = Pcg::new(4);
            DircChip::query_batch(&chip, &pool, &queries, 10, &mut r).len()
        })
        .summary
        .median;

    let base = medians[0].1;
    let mut t = Table::new(&["threads", "median/query", "speedup vs 1 thread"]);
    for &(threads, median) in &medians {
        t.row(&[
            threads.to_string(),
            fmt_duration(median),
            format!("{:.2}x", base / median),
        ]);
    }
    println!("\n=== parallel_scaling: single-query core-shard fan-out ===");
    t.print();
    println!(
        "batch of 32: serial {} vs pooled matrix {} ({:.2}x)",
        fmt_duration(serial_batch),
        fmt_duration(matrix_batch),
        serial_batch / matrix_batch
    );

    let four = medians
        .iter()
        .find(|(threads, _)| *threads == 4)
        .map(|&(_, m)| m)
        .unwrap();
    let speedup = base / four;
    println!("single-query speedup at 4 threads: {speedup:.2}x");
    // The hard floor defaults to the 2x contract on developer machines;
    // CI runners are throttled and noisy-neighboured, so the workflow
    // relaxes it through DIRC_BENCH_MIN_SPEEDUP rather than flaking.
    let min_speedup: f64 = std::env::var("DIRC_BENCH_MIN_SPEEDUP")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);
    let host_cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    if host_cores >= 4 {
        assert!(
            speedup >= min_speedup,
            "expected >={min_speedup}x single-query speedup at 4 threads on a \
             {host_cores}-core host, got {speedup:.2}x (override via DIRC_BENCH_MIN_SPEEDUP)"
        );
    } else {
        eprintln!(
            "(host has only {host_cores} cores; skipping the >={min_speedup}x speedup assertion)"
        );
    }

    b.report("parallel_scaling");
}
