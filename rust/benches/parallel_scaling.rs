//! Parallel sharded plan execution: pool-width scaling of the chip's
//! per-core job fan-out, plus the queries × cores batch matrix. Proves
//! pooled `QueryPlan`s buy near-linear speedup while staying
//! bit-identical to the serial plan.
//!
//! ```bash
//! cargo bench --bench parallel_scaling
//! ```

use std::sync::Arc;

use dirc_rag::bench::{fmt_duration, Bench, Table};
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::retrieval::plan::{Exec, QueryPlan};
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::util::pool::ThreadPool;
use dirc_rag::util::rng::Pcg;

fn main() {
    // Full 4 MB chip: 8192 docs x 512 dims INT8 on 16 cores.
    let (n, dim) = (8192usize, 512usize);
    let mut rng = Pcg::new(1);
    let fp: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.05).collect();
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let cfg = ChipConfig { map_points: 80, ..ChipConfig::paper_default(dim, Metric::Mips) };
    let chip = Arc::new(DircChip::build(cfg, &db));
    let q: Vec<i8> = (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect();

    // Every configuration below is the same validated plan with a
    // different Exec — the only knob the sweep turns.
    let base = QueryPlan::topk(10).seed(9).build().unwrap();

    // Correctness first: the pooled plan must be bit-identical to the
    // serial plan before any of the timings below mean anything.
    {
        let pool = Arc::new(ThreadPool::new(4));
        let s = chip.execute(&q, &base.with_exec(Exec::Serial));
        let p = chip.execute(&q, &base.with_exec(Exec::Pool(pool)));
        assert_eq!(s.topk, p.topk, "pooled ranking diverged from serial");
        assert_eq!(s.stats.cycles, p.stats.cycles);
        assert_eq!(s.stats.sense, p.stats.sense);
    }

    let mut b = Bench::new();
    let thread_counts = [1usize, 2, 4, 8, 16];
    let timing = base.with_seed(2);
    let mut medians: Vec<(usize, f64)> = Vec::new();
    for &threads in &thread_counts {
        let plan = if threads == 1 {
            timing.with_exec(Exec::Serial)
        } else {
            timing.with_exec(Exec::Pool(Arc::new(ThreadPool::new(threads))))
        };
        let r = b.run(&format!("single query (16 cores), {threads} threads"), || {
            chip.execute(&q, &plan).stats.cycles
        });
        medians.push((threads, r.summary.median));
    }

    // Batch throughput: 32 queries serial vs the queries x cores matrix.
    let mut qrng = Pcg::new(3);
    let queries: Vec<Vec<i8>> = (0..32)
        .map(|_| (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let batch_plan = base.with_seed(4);
    let serial_batch = b
        .run("batch of 32 queries, serial loop", || {
            chip.execute_batch(&queries, &batch_plan.with_exec(Exec::Serial))
                .iter()
                .map(|o| o.stats.cycles)
                .sum::<u64>()
        })
        .summary
        .median;
    let pool = Arc::new(ThreadPool::new(4));
    let matrix = batch_plan.with_exec(Exec::Pool(Arc::clone(&pool)));
    let matrix_batch = b
        .run("batch of 32 queries, 4-worker pool (queries x cores matrix)", || {
            chip.execute_batch(&queries, &matrix).len()
        })
        .summary
        .median;

    let base_median = medians[0].1;
    let mut t = Table::new(&["threads", "median/query", "speedup vs 1 thread"]);
    for &(threads, median) in &medians {
        t.row(&[
            threads.to_string(),
            fmt_duration(median),
            format!("{:.2}x", base_median / median),
        ]);
    }
    println!("\n=== parallel_scaling: single-query core-shard fan-out ===");
    t.print();
    println!(
        "batch of 32: serial {} vs pooled matrix {} ({:.2}x)",
        fmt_duration(serial_batch),
        fmt_duration(matrix_batch),
        serial_batch / matrix_batch
    );

    let four = medians
        .iter()
        .find(|(threads, _)| *threads == 4)
        .map(|&(_, m)| m)
        .unwrap();
    let speedup = base_median / four;
    println!("single-query speedup at 4 threads: {speedup:.2}x");
    // The hard floor defaults to the 2x contract on developer machines;
    // CI runners are throttled and noisy-neighboured, so the workflow
    // relaxes it through DIRC_BENCH_MIN_SPEEDUP rather than flaking.
    let min_speedup: f64 = std::env::var("DIRC_BENCH_MIN_SPEEDUP")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);
    let host_cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    if host_cores >= 4 {
        assert!(
            speedup >= min_speedup,
            "expected >={min_speedup}x single-query speedup at 4 threads on a \
             {host_cores}-core host, got {speedup:.2}x (override via DIRC_BENCH_MIN_SPEEDUP)"
        );
    } else {
        eprintln!(
            "(host has only {host_cores} cores; skipping the >={min_speedup}x speedup assertion)"
        );
    }

    b.report("parallel_scaling");
}
