//! Hot-path microbenchmarks — the §Perf instrument panel.
//!
//! Times every stage of the serve path in isolation so the performance
//! pass can attribute end-to-end cost: exact scoring, sensing simulation,
//! top-k, PJRT execution, engine retrieve, and the full coordinator
//! round-trip. Results are logged in EXPERIMENTS.md §Perf.

use std::sync::Arc;

use dirc_rag::bench::{fmt_si, Bench};
use dirc_rag::coordinator::{Engine, ServingEngine, SimEngine};
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::{mips_scores, Metric};
use dirc_rag::retrieval::topk::topk_from_scores;
use dirc_rag::runtime::PjrtRuntime;
use dirc_rag::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let (n, dim) = (8192usize, 512usize);
    let mut rng = Pcg::new(1);
    let fp: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.05).collect();
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let q: Vec<i8> = (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect();

    let mut b = Bench::new();

    // --- L3 pure compute stages. ---
    let r = b.run("exact i8 scores, 8192x512 (4 MB sweep)", || {
        mips_scores(&db.values, n, dim, &q)
    });
    let docs_per_s = n as f64 / r.summary.median;
    eprintln!("    -> {} doc-scores/s", fmt_si(docs_per_s));

    let scores: Vec<f64> = mips_scores(&db.values, n, dim, &q)
        .into_iter()
        .map(|v| v as f64)
        .collect();
    b.run("top-10 of 8192 scores", || topk_from_scores(&scores, 0, 10));

    let cfg = ChipConfig { map_points: 150, ..ChipConfig::paper_default(dim, Metric::Mips) };
    let chip = DircChip::build(cfg.clone(), &db);
    b.run("macro sense (error injection), 1 core", || {
        let mut r = Pcg::new(2);
        chip.cores()[0].macro_().sense(&mut r).1.flips
    });
    b.run("full chip query (sim engine path)", || {
        chip.execute(&q, &QueryPlan::topk(10).seed(3).build().unwrap()).stats.cycles
    });

    // --- PJRT stages (need artifacts). ---
    let dir = dirc_rag::runtime::artifacts_dir();
    if dir.join("manifest.json").exists() {
        let rt = Arc::new(PjrtRuntime::new(dir)?);
        let art = rt.manifest().best_block("mips", 512, dim)?.name.clone();
        let block = rt.upload_db(&art, &db.values[..512 * dim], 512, dim, None)?;
        b.run("PJRT mips block 1024x512 (pallas grid loop)", || {
            rt.mips_scores(&block, &q).unwrap().len()
        });

        // The serving fast path: plain fused dot, whole DB in one exec.
        let plain1k = rt.manifest().best_block("mips_plain", 512, dim)?.name.clone();
        let pb1 = rt.upload_db(&plain1k, &db.values[..512 * dim], 512, dim, None)?;
        b.run("PJRT mips_plain block 1024x512 (fused dot)", || {
            rt.mips_scores(&pb1, &q).unwrap().len()
        });
        let plain8k = rt.manifest().best_block("mips_plain", n, dim)?.name.clone();
        let pb8 = rt.upload_db(&plain8k, &db.values, n, dim, None)?;
        b.run("PJRT mips_plain block 8192x512 (whole 4 MB DB)", || {
            rt.mips_scores(&pb8, &q).unwrap().len()
        });

        let tk = rt
            .manifest()
            .best_block("mips_topk", 512, dim)
            .map(|a| a.name.clone());
        if let Ok(tk) = tk {
            let tkb = rt.upload_db(&tk, &db.values[..512 * dim], 512, dim, None)?;
            b.run("PJRT fused topk block 1024x512", || {
                rt.topk(&tkb, &q, None).unwrap().len()
            });
        }

        let feats = vec![0.01f32; 2048];
        b.run("PJRT embed b1", || rt.embed(&feats, 1).unwrap().len());

        let sim = SimEngine::new(cfg.clone(), &db);
        let plan5 = QueryPlan::topk(10).seed(5).build().unwrap();
        b.run("SimEngine.retrieve (4 MB, errors+stats)", || {
            sim.retrieve(&q, &plan5).topk.len()
        });

        let srv = ServingEngine::new(cfg, &db, Arc::clone(&rt))?;
        let plan6 = QueryPlan::topk(10).seed(6).build().unwrap();
        b.run("ServingEngine.retrieve (4 MB, PJRT+corrections)", || {
            srv.retrieve(&q, &plan6).topk.len()
        });
    } else {
        eprintln!("(artifacts not built: skipping PJRT stages)");
    }

    b.report("hotpath");
    Ok(())
}
