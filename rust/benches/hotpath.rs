//! Hot-path microbenchmarks — the §Perf instrument panel — plus the
//! packed-kernel host-throughput gate.
//!
//! Times every stage of the serve path in isolation so the performance
//! pass can attribute end-to-end cost: exact scoring, sensing simulation,
//! top-k, PJRT execution, engine retrieve, and the full coordinator
//! round-trip. Results are logged in EXPERIMENTS.md §Perf.
//!
//! The gate section races the two `ScoreBackend`s over the chip-resident
//! 4 MB corpus — the element walk the sense path used to run against the
//! packed bit-plane popcount kernel — asserting in the *same run* that
//! both are bit-identical (integer scores, merged top-k, census), then
//! enforcing a packed-over-walk speedup floor and a docs-scored/sec
//! floor, and emitting the `BENCH_6.json` trajectory artifact.
//!
//! ```bash
//! cargo bench --bench hotpath
//! ```
//!
//! Env knobs:
//!
//! * `DIRC_BENCH_MIN_PACKED_SPEEDUP` — packed-over-walk floor on the
//!   clean-score race (default 4.0, the acceptance target with popcount
//!   hardware codegen; CI builds this step with `-C target-cpu=native`
//!   and may pin its own floor — generic codegen lowers `count_ones()`
//!   to a ~12-op SWAR sequence and lands far lower);
//! * `DIRC_BENCH_MIN_DOCS_PER_S` — packed docs-scored/sec smoke floor
//!   (default 1e5 — any host clears it; raise it on pinned hardware);
//! * `DIRC_BENCH_FAST=1` — shrink measurement windows and the batch;
//! * `DIRC_BENCH_OUT` — artifact path (default `BENCH_6.json` at the
//!   workspace root).

use std::sync::Arc;

use dirc_rag::bench::{fmt_duration, fmt_si, Bench};
use dirc_rag::coordinator::{Engine, ServingEngine, SimEngine};
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::retrieval::plan::{QueryPlan, ScoreBackend, StatsDetail};
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::{mips_scores, Metric};
use dirc_rag::retrieval::topk::topk_from_scores;
use dirc_rag::runtime::PjrtRuntime;
use dirc_rag::util::json::Json;
use dirc_rag::util::pool::ThreadPool;
use dirc_rag::util::rng::Pcg;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("DIRC_BENCH_FAST").ok().as_deref() == Some("1");
    let (n, dim) = (8192usize, 512usize);
    let mut rng = Pcg::new(1);
    let fp: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.05).collect();
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let q: Vec<i8> = (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect();
    assert_eq!(db.stored_bytes(), 4 << 20, "corpus must be exactly 4 MB INT8");

    let mut b = Bench::new();

    // --- L3 pure compute stages. ---
    let r = b.run("exact i8 scores, 8192x512 (4 MB sweep)", || {
        mips_scores(&db.values, n, dim, &q)
    });
    let docs_per_s = n as f64 / r.summary.median;
    eprintln!("    -> {} doc-scores/s", fmt_si(docs_per_s));

    let scores: Vec<f64> = mips_scores(&db.values, n, dim, &q)
        .into_iter()
        .map(|v| v as f64)
        .collect();
    b.run("top-10 of 8192 scores", || topk_from_scores(&scores, 0, 10));

    let cfg = ChipConfig {
        map_points: if fast { 40 } else { 150 },
        ..ChipConfig::paper_default(dim, Metric::Mips)
    };
    let chip = DircChip::build(cfg.clone(), &db);
    b.run("macro sense (error injection), 1 core", || {
        let mut r = Pcg::new(2);
        chip.cores()[0].macro_().sense(&mut r).1.flips
    });
    b.run("full chip query (sim engine path)", || {
        chip.execute(&q, &QueryPlan::topk(10).seed(3).build().unwrap()).stats.cycles
    });

    // --- The ScoreBackend gate: element walk vs packed popcount. ---
    // Bit-identity before any numbers, in this very run: a fast kernel
    // that drifts from the reference is a failure, not a result.
    let qp = chip.pack_query(&q);
    {
        let mut scratch = Vec::new();
        for core in chip.cores() {
            core.macro_().clean_scores_packed_into(&qp, &mut scratch);
            assert_eq!(
                scratch,
                core.macro_().clean_scores(&q),
                "packed kernel diverged from the element walk"
            );
        }
        let base = QueryPlan::topk(10).seed(7).build().unwrap();
        let walk = chip.execute(&q, &base.with_backend(ScoreBackend::Walk));
        let pack = chip.execute(&q, &base);
        assert_eq!(walk.topk, pack.topk, "backends diverged under sensing");
        assert_eq!(walk.stats.sense, pack.stats.sense);
        assert_eq!(walk.stats.cycles, pack.stats.cycles);
        assert_eq!(walk.stats.docs_scored, pack.stats.docs_scored);
        assert_eq!(walk.stats.energy_j.to_bits(), pack.stats.energy_j.to_bits());
    }

    // The kernel race: one full clean-score pass of the resident corpus
    // (all 16 macros) per iteration. The walk arm is exactly the scorer
    // the sense path ran before the packed backend existed — per-core
    // `clean_scores`, allocation included; the packed arm re-packs the
    // query each pass (as `execute` does) and reuses one scratch buffer.
    let walk_s = b
        .run("clean scores: element walk (4 MB, 16 cores)", || {
            chip.cores()
                .iter()
                .map(|c| c.macro_().clean_scores(&q).len())
                .sum::<usize>()
        })
        .summary
        .median;
    let mut scratch = Vec::new();
    let packed_s = b
        .run("clean scores: packed popcount (4 MB, 16 cores)", || {
            let qp = chip.pack_query(&q);
            let mut total = 0usize;
            for c in chip.cores() {
                c.macro_().clean_scores_packed_into(&qp, &mut scratch);
                total += scratch.len();
            }
            total
        })
        .summary
        .median;
    let speedup = walk_s / packed_s;
    let docs_per_s_packed = n as f64 / packed_s;
    eprintln!(
        "    -> walk {} vs packed {} per 4 MB pass: {speedup:.2}x, {} doc-scores/s packed",
        fmt_duration(walk_s),
        fmt_duration(packed_s),
        fmt_si(docs_per_s_packed),
    );

    // The batch serve path under each backend: queries x cores job
    // matrix on a pool of 4, census at Counters (identical results —
    // pinned below — so the cycle/energy assembly is pure overhead).
    let n_queries = if fast { 8 } else { 32 };
    let queries: Vec<Vec<i8>> = (0..n_queries)
        .map(|_| (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect())
        .collect();
    let pool = Arc::new(ThreadPool::new(4));
    let host_plan = |backend: ScoreBackend| {
        QueryPlan::topk(10)
            .seed(11)
            .pool(Arc::clone(&pool))
            .detail(StatsDetail::Counters)
            .backend(backend)
            .build()
            .expect("host timing plan")
    };
    let wplan = host_plan(ScoreBackend::Walk);
    let pplan = host_plan(ScoreBackend::Packed);
    let wouts = chip.execute_batch(&queries, &wplan);
    let pouts = chip.execute_batch(&queries, &pplan);
    for (w, p) in wouts.iter().zip(&pouts) {
        assert_eq!(w.topk, p.topk, "backends diverged on the batch path");
        assert_eq!(w.stats.sense, p.stats.sense);
        assert_eq!(w.stats.docs_scored, p.stats.docs_scored);
    }
    let batch_walk_s = b
        .run("execute_batch walk (pool of 4)", || {
            chip.execute_batch(&queries, &wplan).len()
        })
        .summary
        .median;
    let batch_packed_s = b
        .run("execute_batch packed (pool of 4)", || {
            chip.execute_batch(&queries, &pplan).len()
        })
        .summary
        .median;
    let batch_docs_per_s = (n_queries * n) as f64 / batch_packed_s;
    eprintln!(
        "    -> batch of {n_queries}: walk {} vs packed {} ({:.2}x), {} doc-scores/s",
        fmt_duration(batch_walk_s),
        fmt_duration(batch_packed_s),
        batch_walk_s / batch_packed_s,
        fmt_si(batch_docs_per_s),
    );

    // --- PJRT stages (need artifacts). ---
    let dir = dirc_rag::runtime::artifacts_dir();
    if dir.join("manifest.json").exists() {
        let rt = Arc::new(PjrtRuntime::new(dir)?);
        let art = rt.manifest().best_block("mips", 512, dim)?.name.clone();
        let block = rt.upload_db(&art, &db.values[..512 * dim], 512, dim, None)?;
        b.run("PJRT mips block 1024x512 (pallas grid loop)", || {
            rt.mips_scores(&block, &q).unwrap().len()
        });

        // The serving fast path: plain fused dot, whole DB in one exec.
        let plain1k = rt.manifest().best_block("mips_plain", 512, dim)?.name.clone();
        let pb1 = rt.upload_db(&plain1k, &db.values[..512 * dim], 512, dim, None)?;
        b.run("PJRT mips_plain block 1024x512 (fused dot)", || {
            rt.mips_scores(&pb1, &q).unwrap().len()
        });
        let plain8k = rt.manifest().best_block("mips_plain", n, dim)?.name.clone();
        let pb8 = rt.upload_db(&plain8k, &db.values, n, dim, None)?;
        b.run("PJRT mips_plain block 8192x512 (whole 4 MB DB)", || {
            rt.mips_scores(&pb8, &q).unwrap().len()
        });

        let tk = rt
            .manifest()
            .best_block("mips_topk", 512, dim)
            .map(|a| a.name.clone());
        if let Ok(tk) = tk {
            let tkb = rt.upload_db(&tk, &db.values[..512 * dim], 512, dim, None)?;
            b.run("PJRT fused topk block 1024x512", || {
                rt.topk(&tkb, &q, None).unwrap().len()
            });
        }

        let feats = vec![0.01f32; 2048];
        b.run("PJRT embed b1", || rt.embed(&feats, 1).unwrap().len());

        let sim = SimEngine::new(cfg.clone(), &db);
        let plan5 = QueryPlan::topk(10).seed(5).build().unwrap();
        b.run("SimEngine.retrieve (4 MB, errors+stats)", || {
            sim.retrieve(&q, &plan5).topk.len()
        });

        let srv = ServingEngine::new(cfg, &db, Arc::clone(&rt))?;
        let plan6 = QueryPlan::topk(10).seed(6).build().unwrap();
        b.run("ServingEngine.retrieve (4 MB, PJRT+corrections)", || {
            srv.retrieve(&q, &plan6).topk.len()
        });
    } else {
        eprintln!("(artifacts not built: skipping PJRT stages)");
    }

    // The trajectory artifact lands *before* the throughput floors, so a
    // tripped gate still leaves the failing run's numbers on disk for
    // inspection (bit-identity was already asserted above — those gates
    // run before any timing).
    let min_speedup = env_f64("DIRC_BENCH_MIN_PACKED_SPEEDUP", 4.0);
    let min_docs_per_s = env_f64("DIRC_BENCH_MIN_DOCS_PER_S", 1e5);
    let out = std::env::var("DIRC_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_6.json").into());
    let json = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        (
            "corpus",
            Json::obj(vec![
                ("docs", Json::num(n as f64)),
                ("dim", Json::num(dim as f64)),
                ("stored_mb", Json::num(db.stored_bytes() as f64 / (1 << 20) as f64)),
                ("batch_queries", Json::num(n_queries as f64)),
            ]),
        ),
        // The batch plan, recorded so the artifact is self-describing.
        ("plan", {
            let plan = host_plan(ScoreBackend::Packed);
            Json::obj(vec![
                ("k", Json::num(plan.k() as f64)),
                ("backend", Json::str(plan.backend().name())),
                ("exec", Json::str(plan.exec().name())),
                ("rng", Json::str(format!("{:?}", plan.rng()))),
                ("detail", Json::str(format!("{:?}", plan.detail()))),
            ])
        }),
        (
            "kernel",
            Json::obj(vec![
                ("walk_s_per_pass", Json::num(walk_s)),
                ("packed_s_per_pass", Json::num(packed_s)),
                ("speedup", Json::num(speedup)),
                ("docs_per_s_walk", Json::num(n as f64 / walk_s)),
                ("docs_per_s_packed", Json::num(docs_per_s_packed)),
            ]),
        ),
        (
            "batch",
            Json::obj(vec![
                ("walk_s_per_batch", Json::num(batch_walk_s)),
                ("packed_s_per_batch", Json::num(batch_packed_s)),
                ("speedup", Json::num(batch_walk_s / batch_packed_s)),
                ("docs_per_s_packed", Json::num(batch_docs_per_s)),
            ]),
        ),
        (
            "gates",
            Json::obj(vec![
                ("min_packed_speedup", Json::num(min_speedup)),
                ("min_docs_per_s", Json::num(min_docs_per_s)),
                ("bit_identity", Json::str("asserted (kernel, execute, batch)")),
            ]),
        ),
    ]);
    std::fs::write(&out, json.to_string_pretty()).expect("write bench artifact");
    println!("wrote {out}");

    assert!(
        speedup >= min_speedup,
        "packed kernel must beat the element walk >= {min_speedup:.2}x on the clean-score \
         race, got {speedup:.2}x (walk {walk_s:.6}s vs packed {packed_s:.6}s per 4 MB pass)"
    );
    assert!(
        docs_per_s_packed >= min_docs_per_s,
        "packed kernel throughput floor: {docs_per_s_packed:.0} docs/s < {min_docs_per_s:.0}"
    );

    b.report("hotpath");
    Ok(())
}
