//! Scaling study (Sec IV.B): latency and energy vs database size (linear
//! scaling claim), precision vs dimension, and the INT4 capacity doubling.

mod common;

use dirc_rag::bench::Table;
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::util::rng::Pcg;

fn chip_for(n: usize, dim: usize, scheme: QuantScheme) -> (DircChip, Vec<i8>) {
    let mut rng = Pcg::new(7);
    let fp: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.05).collect();
    let db = quantize(&fp, n, dim, scheme);
    let cfg = ChipConfig {
        bits: scheme.bits(),
        map_points: 80,
        ..ChipConfig::paper_default(dim, Metric::Mips)
    };
    let q: Vec<i8> = (0..dim)
        .map(|_| rng.int_in(scheme.qmin() as i64, scheme.qmax() as i64) as i8)
        .collect();
    (DircChip::build(cfg, &db), q)
}

fn main() {
    // --- Latency/energy vs DB size (INT8, dim 512). ---
    let dim = 512;
    let sizes = [512usize, 1024, 2048, 4096, 8192];
    let mut t = Table::new(&["DB", "docs", "latency µs", "energy µJ", "µs/MB", "µJ/MB"]);
    let mut per_mb: Vec<(f64, f64)> = Vec::new();
    for &n in &sizes {
        let (chip, q) = chip_for(n, dim, QuantScheme::Int8);
        let stats = chip.execute(&q, &QueryPlan::topk(10).seed(1).build().unwrap()).stats;
        let mb = (n * dim) as f64 / 1e6;
        t.row(&[
            format!("{:.2} MB", mb),
            n.to_string(),
            format!("{:.2}", stats.latency_s * 1e6),
            format!("{:.3}", stats.energy_j * 1e6),
            format!("{:.2}", stats.latency_s * 1e6 / mb),
            format!("{:.3}", stats.energy_j * 1e6 / mb),
        ]);
        per_mb.push((stats.latency_s / mb, stats.energy_j / mb));
    }
    println!("\n=== Scaling: latency & energy vs DB size (INT8, dim 512) ===");
    t.print();
    // Linearity: marginal cost per MB converges (fixed overhead shrinks).
    let last = per_mb.last().unwrap();
    let prev = per_mb[per_mb.len() - 2];
    assert!((last.0 / prev.0 - 1.0).abs() < 0.25, "latency/MB must stabilise");
    assert!((last.1 / prev.1 - 1.0).abs() < 0.25, "energy/MB must stabilise");

    // --- Dimension sweep (same total bytes). ---
    let mut t2 = Table::new(&["dim", "docs (1 MB)", "latency µs", "energy µJ"]);
    for &d in &[128usize, 256, 512, 1024] {
        let n = 1_048_576 / d; // 1 MiB of INT8
        let (chip, q) = chip_for(n, d, QuantScheme::Int8);
        let stats = chip.execute(&q, &QueryPlan::topk(10).seed(2).build().unwrap()).stats;
        t2.row(&[
            d.to_string(),
            n.to_string(),
            format!("{:.2}", stats.latency_s * 1e6),
            format!("{:.3}", stats.energy_j * 1e6),
        ]);
    }
    println!("\n=== Scaling: dimension sweep at fixed 1 MiB ===");
    t2.print();

    // --- INT4 vs INT8 capacity & cost. ---
    let (chip8, q8) = chip_for(8192, dim, QuantScheme::Int8);
    let (chip4, q4) = chip_for(16384, dim, QuantScheme::Int4);
    // Streaming contract: two draws of the shared stream, exactly as
    // the pre-plan API consumed them.
    let mut rng = Pcg::new(3);
    let base = QueryPlan::topk(10).build().unwrap();
    let s8 = chip8.execute(&q8, &base.with_stream(&mut rng)).stats;
    let s4 = chip4.execute(&q4, &base.with_stream(&mut rng)).stats;
    println!(
        "\nINT4 doubles capacity: {} docs (INT4) vs {} docs (INT8) on the same chip;\n\
         full-chip query: INT4 {:.2} µs / {:.3} µJ vs INT8 {:.2} µs / {:.3} µJ",
        chip4.n_docs(),
        chip8.n_docs(),
        s4.latency_s * 1e6,
        s4.energy_j * 1e6,
        s8.latency_s * 1e6,
        s8.energy_j * 1e6,
    );
    assert_eq!(chip4.n_docs(), 2 * chip8.n_docs());
    assert!(s4.latency_s < s8.latency_s, "INT4 full chip must be faster than INT8");
}
