//! Trace-driven tail-latency harness: seeded Zipfian/bursty mixed
//! query+mutation traffic through (a) the deterministic queueing-aware
//! latency model and (b) the live coordinator, with per-tenant
//! p50/p95/p99 accounting. Emits the `BENCH_9.json` artifact (override
//! the path with `DIRC_BENCH_OUT`).
//!
//! ```bash
//! cargo bench --bench load_tail
//! ```
//!
//! Gates:
//!
//! * the trace generator is deterministic: two generations from one
//!   seed have equal digests, and two queueing-model runs over them
//!   report bit-identical percentiles;
//! * every reported percentile set is finite and monotone
//!   (p50 <= p95 <= p99), per tenant and globally, in both the model
//!   and the live coordinator snapshot;
//! * tail isolation: with a 3:1 gold:light DRR mix saturated at 1.5x
//!   modeled capacity, the light tenant's modeled p99 stays within
//!   `DIRC_BENCH_TAIL_FACTOR` (default 25x) of its unloaded p99, and
//!   under the gold tenant's p99 — the heavy tenant cannot export its
//!   queueing tail;
//! * the live replay pushes the full trace (>= 10k queries) through
//!   `Coordinator::submit_for` and every submission completes, with the
//!   per-tenant served counters summing to the global.

use std::sync::Arc;
use std::time::Duration;

use dirc_rag::bench::{fmt_duration, Bench, Table};
use dirc_rag::coordinator::batcher::BatchPolicy;
use dirc_rag::coordinator::{
    Coordinator, CoordinatorConfig, Engine, SimEngine, TenantSpec,
};
use dirc_rag::data::{SynthDataset, SynthParams};
use dirc_rag::dirc::chip::ChipConfig;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::QueryPlan;
use dirc_rag::util::json::Json;
use dirc_rag::workload::{
    queueing, runner, LoadReport, QueueModelConfig, Trace, TraceConfig,
};

const N_DOCS: usize = 2048;
const DIM: usize = 256;
const DISTINCT: usize = 192;
const TENANT_NAMES: [&str; 2] = ["gold", "light"];
const WEIGHTS: [u32; 2] = [3, 1];
/// Gold floods with 90% of arrivals but only 75% of the DRR capacity —
/// the light tenant's guaranteed share keeps its own load modest.
const MIX: [f64; 2] = [0.9, 0.1];

fn assert_monotone(label: &str, p50: f64, p95: f64, p99: f64) {
    assert!(
        p50.is_finite() && p95.is_finite() && p99.is_finite(),
        "{label}: non-finite percentile ({p50} / {p95} / {p99})"
    );
    assert!(
        p50 <= p95 && p95 <= p99,
        "{label}: percentiles not monotone (p50 {p50} / p95 {p95} / p99 {p99})"
    );
}

fn check_report(label: &str, rep: &LoadReport) {
    assert_monotone(&format!("{label} global"), rep.global.p50_s, rep.global.p95_s, rep.global.p99_s);
    for t in &rep.tenants {
        assert_monotone(&format!("{label} tenant {}", t.name), t.p50_s, t.p95_s, t.p99_s);
        assert!(t.p50_s > 0.0, "{label} tenant {}: zero p50", t.name);
    }
}

fn main() {
    let fast = std::env::var("DIRC_BENCH_FAST").ok().as_deref() == Some("1");
    let tail_factor: f64 = std::env::var("DIRC_BENCH_TAIL_FACTOR")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(25.0);
    // The acceptance floor: >= 10k queries through the coordinator even
    // in fast mode.
    let events = if fast { 10_000 } else { 16_000 };

    eprintln!("generating {N_DOCS} x {DIM} corpus + building chip...");
    let params = SynthParams {
        topics: 32,
        doc_noise: 0.5,
        rels_per_query: 1,
        extra_rel_range: 1,
        query_noise: 0.5,
        confuse: 0.5,
        aniso: 1.0,
        seed: 909,
    };
    let ds = SynthDataset::generate(N_DOCS, DISTINCT, DIM, &params);
    let db = quantize(&ds.docs, N_DOCS, DIM, QuantScheme::Int8);
    let chip_cfg = ChipConfig {
        map_points: if fast { 40 } else { 80 },
        ..ChipConfig::paper_default(DIM, Metric::Cosine)
    };
    let pool = Arc::new(dirc_rag::util::pool::ThreadPool::new(
        dirc_rag::util::pool::default_threads(),
    ));
    let engine = Arc::new(SimEngine::with_pool(chip_cfg, &db, Some(pool)));

    // Per-distinct-query service times from the cycle model, one seeded
    // batch execution over the query pool.
    let plan = QueryPlan::topk(10).seed(17).build().expect("plan");
    let queries_i8: Vec<Vec<i8>> = (0..DISTINCT)
        .map(|qi| quantize(ds.query(qi), 1, DIM, QuantScheme::Int8).values)
        .collect();
    let outs = engine.chip().execute_batch(&queries_i8, &plan);
    let service_s: Vec<f64> = outs.iter().map(|o| o.stats.latency_s).collect();
    let mean_service = service_s.iter().sum::<f64>() / service_s.len() as f64;

    let workers = 2usize;
    let capacity_qps = workers as f64 / mean_service;
    let qcfg = QueueModelConfig {
        workers,
        batch_max: 32,
        batch_max_wait_s: 20e-6,
        run_max: 8,
        weights: WEIGHTS.to_vec(),
        tenant_names: TENANT_NAMES.iter().map(|s| s.to_string()).collect(),
        mutation_max_defer_s: 500e-6,
        write_s_per_doc: 100e-6,
    };
    let trace_cfg = |qps: f64| TraceConfig {
        n_queries: events,
        distinct_queries: DISTINCT,
        n_docs: N_DOCS,
        zipf_exponent: 1.1,
        target_qps: qps,
        tenant_mix: MIX.to_vec(),
        mutate_every: 500,
        mutation_docs: 8,
        storm_mutations: 8,
        seed: 0xB9,
        ..TraceConfig::default()
    };

    let mut b = Bench::new();

    // --- Determinism gate: trace schedule + model percentiles ---------
    let sat_cfg = trace_cfg(1.5 * capacity_qps);
    let trace = Trace::generate(&sat_cfg);
    assert!(trace.n_queries() >= 10_000, "acceptance floor: >= 10k queries");
    assert_eq!(
        trace.digest(),
        Trace::generate(&sat_cfg).digest(),
        "identical seeds must reproduce identical trace schedules"
    );
    let saturated = queueing::simulate(&trace, &service_s, &qcfg);
    {
        let again = queueing::simulate(&Trace::generate(&sat_cfg), &service_s, &qcfg);
        assert_eq!(
            saturated.digest(),
            again.digest(),
            "identical runs must report bit-identical percentiles"
        );
        for (a, c) in saturated.tenants.iter().zip(&again.tenants) {
            assert_eq!(a.p99_s.to_bits(), c.p99_s.to_bits(), "tenant {} p99 drifted", a.name);
        }
    }

    // --- Queueing-model arms: unloaded vs saturated -------------------
    let unloaded_cfg = trace_cfg(0.02 * capacity_qps);
    let unloaded = queueing::simulate(&Trace::generate(&unloaded_cfg), &service_s, &qcfg);
    let model_s = b
        .run("queueing model (saturated trace)", || {
            queueing::simulate(&Trace::generate(&sat_cfg), &service_s, &qcfg).global.queries
        })
        .summary
        .median;
    check_report("unloaded", &unloaded);
    check_report("saturated", &saturated);

    let light_sat = &saturated.tenants[1];
    let light_un = &unloaded.tenants[1];
    let gold_sat = &saturated.tenants[0];
    assert!(gold_sat.queries > light_sat.queries, "mix must skew toward gold");

    println!("\n=== load_tail: trace-driven tails, {events} queries/arm ===");
    println!(
        "service: mean {} / capacity {:.0} qps ({} workers); offered saturated {:.0} qps, \
         unloaded {:.0} qps",
        fmt_duration(mean_service),
        capacity_qps,
        workers,
        saturated.offered_qps,
        unloaded.offered_qps
    );
    let mut t = Table::new(&["arm / tenant", "n", "p50", "p95", "p99", "max"]);
    for (arm, rep) in [("unloaded", &unloaded), ("saturated", &saturated)] {
        for tl in std::iter::once(&rep.global).chain(rep.tenants.iter()) {
            t.row(&[
                format!("{arm} {}", tl.name),
                format!("{}", tl.queries),
                fmt_duration(tl.p50_s),
                fmt_duration(tl.p95_s),
                fmt_duration(tl.p99_s),
                fmt_duration(tl.max_s),
            ]);
        }
    }
    t.print();
    print!("{}", saturated.render());

    // --- Tail-isolation gates -----------------------------------------
    let inflation = light_sat.p99_s / light_un.p99_s.max(1e-12);
    assert!(
        inflation <= tail_factor,
        "light tenant p99 inflated {inflation:.1}x under saturation \
         (gate {tail_factor}x): {:.2} µs -> {:.2} µs",
        light_un.p99_s * 1e6,
        light_sat.p99_s * 1e6
    );
    assert!(
        light_sat.p99_s <= gold_sat.p99_s,
        "DRR must keep the light tenant's tail under the flooding tenant's: \
         light {:.2} µs vs gold {:.2} µs",
        light_sat.p99_s * 1e6,
        gold_sat.p99_s * 1e6
    );
    println!(
        "tail isolation: light p99 {} unloaded -> {} saturated ({inflation:.1}x, \
         gate {tail_factor}x); gold p99 {}",
        fmt_duration(light_un.p99_s),
        fmt_duration(light_sat.p99_s),
        fmt_duration(gold_sat.p99_s)
    );

    // --- Live replay through the coordinator ---------------------------
    eprintln!("replaying {} events against the live coordinator...", trace.events.len());
    let ccfg = CoordinatorConfig {
        workers,
        batch: BatchPolicy { sizes: vec![32], max_wait: Duration::from_millis(2) },
        tenants: vec![
            TenantSpec { name: "gold".into(), weight: 3, plan: None },
            TenantSpec { name: "light".into(), weight: 1, plan: None },
        ],
        default_plan: plan.clone(),
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_sim(Arc::clone(&engine) as Arc<dyn Engine>, ccfg);
    let queries_fp: Vec<Vec<f32>> =
        (0..DISTINCT).map(|qi| ds.query(qi).to_vec()).collect();
    let tenant_names: Vec<String> = TENANT_NAMES.iter().map(|s| s.to_string()).collect();
    let live_wall = std::time::Instant::now();
    let rep = runner::replay(
        &coord,
        &trace,
        &tenant_names,
        &queries_fp,
        DIM,
        &runner::ReplayOptions::default(),
    )
    .expect("live replay");
    let live_s = live_wall.elapsed().as_secs_f64();
    let snap = coord.shutdown();

    assert_eq!(
        rep.queries_completed,
        trace.n_queries() as u64,
        "every live submission must complete ({} errors)",
        rep.query_errors
    );
    assert_eq!(rep.query_errors, 0, "no submit/recv errors");
    assert_eq!(snap.served, rep.queries_completed, "snapshot counts every query");
    let served_sum: u64 = snap.tenants.iter().map(|t| t.served).sum();
    assert_eq!(served_sum, snap.served, "per-tenant served sums to global");
    assert_monotone(
        "live global",
        snap.host_latency_p50_s,
        snap.host_latency_p95_s,
        snap.host_latency_p99_s,
    );
    for ts in &snap.tenants {
        assert_monotone(
            &format!("live tenant {}", ts.name),
            ts.host_latency_p50_s,
            ts.host_latency_p95_s,
            ts.host_latency_p99_s,
        );
    }
    println!(
        "live replay: {} queries + {} mutations in {} ({:.0} qps wall); \
         host p50/p95/p99 {} / {} / {}",
        rep.queries_completed,
        rep.mutations_completed,
        fmt_duration(live_s),
        rep.queries_completed as f64 / live_s.max(1e-9),
        fmt_duration(snap.host_latency_p50_s),
        fmt_duration(snap.host_latency_p95_s),
        fmt_duration(snap.host_latency_p99_s),
    );

    // --- Artifact -------------------------------------------------------
    let tenant_json = |tl: &dirc_rag::workload::TenantLoad| {
        Json::obj(vec![
            ("name", Json::str(&tl.name)),
            ("queries", Json::num(tl.queries as f64)),
            ("p50_s", Json::num(tl.p50_s)),
            ("p95_s", Json::num(tl.p95_s)),
            ("p99_s", Json::num(tl.p99_s)),
            ("max_s", Json::num(tl.max_s)),
            ("mean_batch_wait_s", Json::num(tl.mean_batch_wait_s)),
            ("mean_queue_wait_s", Json::num(tl.mean_queue_wait_s)),
            ("mean_write_stall_s", Json::num(tl.mean_write_stall_s)),
            ("mean_service_s", Json::num(tl.mean_service_s)),
        ])
    };
    let arm_json = |rep: &LoadReport| {
        Json::obj(vec![
            ("offered_qps", Json::num(rep.offered_qps)),
            ("makespan_s", Json::num(rep.makespan_s)),
            ("mutations", Json::num(rep.mutations as f64)),
            ("global", tenant_json(&rep.global)),
            ("tenants", Json::arr(rep.tenants.iter().map(tenant_json).collect())),
        ])
    };
    let out = std::env::var("DIRC_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_9.json").into());
    let json = Json::obj(vec![
        ("bench", Json::str("load_tail")),
        (
            "workload",
            Json::obj(vec![
                ("events", Json::num(events as f64)),
                ("distinct_queries", Json::num(DISTINCT as f64)),
                ("docs", Json::num(N_DOCS as f64)),
                ("dim", Json::num(DIM as f64)),
                ("zipf_exponent", Json::num(1.1)),
                ("tenant_weights", Json::arr(WEIGHTS.iter().map(|&w| Json::num(f64::from(w))).collect())),
                ("tenant_mix", Json::arr(MIX.iter().map(|&m| Json::num(m)).collect())),
                ("trace_digest", Json::str(&format!("{:016x}", trace.digest()))),
            ]),
        ),
        (
            "model",
            Json::obj(vec![
                ("capacity_qps", Json::num(capacity_qps)),
                ("mean_service_s", Json::num(mean_service)),
                ("model_run_s", Json::num(model_s)),
                ("unloaded", arm_json(&unloaded)),
                ("saturated", arm_json(&saturated)),
                ("light_p99_inflation", Json::num(inflation)),
                ("tail_factor_gate", Json::num(tail_factor)),
            ]),
        ),
        (
            "live",
            Json::obj(vec![
                ("queries", Json::num(rep.queries_completed as f64)),
                ("mutations", Json::num(rep.mutations_completed as f64)),
                ("mutations_skipped", Json::num(rep.mutations_skipped as f64)),
                ("wall_s", Json::num(live_s)),
                ("host_p50_s", Json::num(snap.host_latency_p50_s)),
                ("host_p95_s", Json::num(snap.host_latency_p95_s)),
                ("host_p99_s", Json::num(snap.host_latency_p99_s)),
            ]),
        ),
    ]);
    std::fs::write(&out, json.to_string_pretty()).expect("write bench artifact");
    println!("wrote {out}");

    b.report("load_tail");
}
