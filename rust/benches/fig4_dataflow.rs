//! Fig 4 — the bit-level query-stationary dataflow: cycle budget of one
//! DIRC column pass (16 INT8 embeddings, dim 128), cross-checked between
//! the bit-exact column datapath and the analytical cycle model, plus
//! host wall-clock of the bit-exact path.

use dirc_rag::bench::{Bench, Table};
use dirc_rag::constants::MACRO_DIM;
use dirc_rag::dirc::column::run_column_pass;
use dirc_rag::sim::cycles::CycleModel;
use dirc_rag::util::rng::Pcg;

fn main() {
    let mut rng = Pcg::new(1);
    let docs: Vec<[i8; MACRO_DIM]> = (0..16)
        .map(|_| {
            let mut w = [0i8; MACRO_DIM];
            for v in w.iter_mut() {
                *v = rng.int_in(-128, 127) as i8;
            }
            w
        })
        .collect();
    let query: Vec<i8> = (0..MACRO_DIM).map(|_| rng.int_in(-128, 127) as i8).collect();

    let (results, cycles) = run_column_pass(&docs, &query, 8, true);
    let model = CycleModel::default().macro_pass(16, 8, true);

    let mut t = Table::new(&["phase", "paper (Fig 4)", "bit-exact datapath", "cycle model"]);
    t.row(&[
        "ReRAM sensing".to_string(),
        "128 cycles".to_string(),
        format!("{} cycles", cycles.sense_cycles),
        format!("{} cycles", model.sense),
    ]);
    t.row(&[
        "error detection".to_string(),
        "128 cycles".to_string(),
        format!("{} cycles", cycles.detect_cycles),
        format!("{} cycles", model.detect),
    ]);
    t.row(&[
        "MAC".to_string(),
        "1024 cycles".to_string(),
        format!("{} cycles", cycles.mac_cycles),
        format!("{} cycles", model.mac),
    ]);
    t.row(&[
        "total".to_string(),
        "~1300 cycles (5.2 µs @250MHz)".to_string(),
        format!("{} cycles", cycles.total()),
        format!(
            "{} cycles ({:.2} µs)",
            model.total(),
            CycleModel::default().seconds(model.total()) * 1e6
        ),
    ]);
    println!("\n=== Fig 4: QS dataflow cycle budget (one column pass) ===");
    t.print();

    assert_eq!(cycles.sense_cycles, model.sense);
    assert_eq!(cycles.detect_cycles, model.detect);
    assert_eq!(cycles.mac_cycles, model.mac);

    // Correctness of the bit-exact path against the integer dot.
    for (w, words) in docs.iter().enumerate() {
        let want: i64 = words.iter().zip(&query).map(|(&d, &q)| d as i64 * q as i64).sum();
        assert_eq!(results[w], want);
    }
    println!("\nbit-exact MAC verified against integer dot for all 16 embeddings");

    // INT4 variant: half the planes, quarter the MAC cycles per slot set.
    let (_, c4) = run_column_pass(&docs[..8], &query, 4, true);
    println!(
        "INT4 (8 words): {} sense + {} detect + {} MAC = {} cycles",
        c4.sense_cycles, c4.detect_cycles, c4.mac_cycles, c4.total()
    );

    let mut b = Bench::new();
    b.run("bit-exact column pass (16 INT8 x dim128, host)", || {
        run_column_pass(&docs, &query, 8, true).1.total()
    });
    b.report("fig4_dataflow");
}
