//! Multi-chip fleet serving on the synthetic 4 MB corpus: shard the
//! clustered chip across 1 / 2 / 4 DircChips and chart how the
//! centroid-routed scatter spreads the probed sense work across the
//! fleet. Emits the `BENCH_8.json` trajectory artifact (override the
//! path with `DIRC_BENCH_OUT`).
//!
//! ```bash
//! cargo bench --bench fleet_scaling
//! ```
//!
//! Gates (deterministic — the census comes from the simulator):
//!
//! * every shard count returns bit-identical results (ids AND score
//!   bits) to the bare single chip on the union corpus, per query —
//!   checked before any scaling number is reported;
//! * pruned P@{1,5,10} holds >= 95% of the exhaustive baseline;
//! * the busiest chip of the 4-shard fleet senses <= half the macros
//!   the single chip does — the scatter actually spreads the work.

use dirc_rag::bench::{fmt_duration, Bench, Table};
use dirc_rag::data::{SynthDataset, SynthParams};
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::eval::precision_at_k;
use dirc_rag::fleet::DircFleet;
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::{PlanOutput, QueryPlan};
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::Prune;
use dirc_rag::util::json::Json;

const N_CLUSTERS: usize = 128;
// 8 of 128 clusters probed: enough scattered macro touches that a
// 4-shard split has headroom to spread them (E[busiest of 4] is well
// under half the total), while still pruning ~94% of the corpus.
const NPROBE: usize = 8;
const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

fn mean_precision(outs: &[PlanOutput], ds: &SynthDataset, k: usize) -> f64 {
    let n = outs.len() as f64;
    outs.iter()
        .enumerate()
        .map(|(qi, o)| precision_at_k(&o.topk, &ds.qrels[qi], k))
        .sum::<f64>()
        / n
}

fn main() {
    let fast = std::env::var("DIRC_BENCH_FAST").ok().as_deref() == Some("1");
    // The full 4 MB chip of the cluster_pruning bench: 8192 docs x 512
    // dims INT8 on 16 cores, topic-structured so precision is
    // meaningful and the centroid router has real structure to shard by.
    let (n, dim) = (8192usize, 512usize);
    let n_queries = if fast { 24 } else { 64 };
    let params = SynthParams {
        topics: 32,
        doc_noise: 0.35,
        rels_per_query: 1,
        extra_rel_range: 1,
        query_noise: 0.35,
        confuse: 0.4,
        aniso: 1.0,
        seed: 4242,
    };
    eprintln!("generating {n} x {dim} corpus + building clustered chip...");
    let ds = SynthDataset::generate(n, n_queries, dim, &params);
    let db = quantize(&ds.docs, n, dim, QuantScheme::Int8);
    let cfg = ChipConfig {
        map_points: if fast { 40 } else { 80 },
        cluster: ClusterPolicy { n_clusters: N_CLUSTERS, nprobe: NPROBE, kmeans_iters: 8 },
        ..ChipConfig::paper_default(dim, Metric::Cosine)
    };
    assert_eq!(db.stored_bytes(), 4 << 20, "corpus must be exactly 4 MB INT8");
    let chip = DircChip::build(cfg.clone(), &db);

    let queries: Vec<Vec<i8>> = (0..n_queries)
        .map(|qi| quantize(ds.query(qi), 1, dim, QuantScheme::Int8).values)
        .collect();

    // Single-chip reference bits (the fleet must reproduce these
    // exactly) and the exhaustive precision baseline, both under the
    // same seeded nonce stream.
    let plan = QueryPlan::topk(10).prune(Prune::Default).seed(17).build().expect("plan");
    let nonces = plan.nonces(n_queries);
    let single = chip.execute_batch(&queries, &plan);
    let ex_plan = QueryPlan::topk(10).prune(Prune::None).seed(17).build().expect("plan");
    let exhaustive = chip.execute_batch(&queries, &ex_plan);

    let mut b = Bench::new();
    let mut t = Table::new(&[
        "fleet",
        "per-chip macros/q",
        "busiest/q",
        "spread vs 1 chip",
        "host wall/q",
    ]);
    // (chips, per-chip macros/query, busiest/query, host seconds)
    let mut rows: Vec<(usize, Vec<f64>, f64, f64)> = Vec::new();
    for &chips in &SHARD_COUNTS {
        let fleet = DircFleet::build(cfg.clone(), &db, chips);
        let mut per_chip = vec![0u64; chips];
        for (qi, q) in queries.iter().enumerate() {
            let (out, shard_stats) = fleet.execute_scatter(q, &plan.with_nonce(nonces[qi]));
            assert_eq!(
                out.topk.len(),
                single[qi].topk.len(),
                "fleet x{chips} changed the result count (query {qi})"
            );
            for (a, s) in out.topk.iter().zip(&single[qi].topk) {
                assert_eq!(
                    a.doc_id, s.doc_id,
                    "fleet x{chips} diverged from the single chip (query {qi})"
                );
                assert_eq!(
                    a.score.to_bits(),
                    s.score.to_bits(),
                    "fleet x{chips} perturbed score bits (query {qi}, doc {})",
                    a.doc_id
                );
            }
            for (s, st) in shard_stats.iter().enumerate() {
                if let Some(st) = st {
                    per_chip[s] += st.macros_sensed as u64;
                }
            }
        }
        let nq = n_queries as f64;
        let per_chip: Vec<f64> = per_chip.iter().map(|&m| m as f64 / nq).collect();
        let busiest = per_chip.iter().copied().fold(0.0f64, f64::max);
        let host = b
            .run(&format!("fleet x{chips} scatter-gather"), || {
                queries
                    .iter()
                    .enumerate()
                    .map(|(qi, q)| {
                        fleet.execute(q, &plan.with_nonce(nonces[qi])).topk.len()
                    })
                    .sum::<usize>()
            })
            .summary
            .median;
        rows.push((chips, per_chip, busiest, host / nq));
    }
    let single_busiest = rows[0].2;
    for (chips, per_chip, busiest, host) in &rows {
        t.row(&[
            format!("{chips} chip{}", if *chips > 1 { "s" } else { "" }),
            format!(
                "[{}]",
                per_chip.iter().map(|m| format!("{m:.1}")).collect::<Vec<_>>().join(", ")
            ),
            format!("{busiest:.1}"),
            format!("{:.2}x", single_busiest / busiest.max(1e-9)),
            fmt_duration(*host),
        ]);
    }
    println!("\n=== fleet_scaling: centroid-routed sharding on the 4 MB corpus ===");
    t.print();

    let p1 = mean_precision(&single, &ds, 1);
    let p5 = mean_precision(&single, &ds, 5);
    let p10 = mean_precision(&single, &ds, 10);
    let e1 = mean_precision(&exhaustive, &ds, 1);
    let e5 = mean_precision(&exhaustive, &ds, 5);
    let e10 = mean_precision(&exhaustive, &ds, 10);
    println!(
        "precision (pruned, fleet == single chip by the equivalence gate): \
         P@1 {p1:.4} / P@5 {p5:.4} / P@10 {p10:.4} \
         (exhaustive {e1:.4} / {e5:.4} / {e10:.4})"
    );

    // The acceptance gates (deterministic).
    for (k, p, e) in [(1, p1, e1), (5, p5, e5), (10, p10, e10)] {
        assert!(
            p >= 0.95 * e,
            "pruned P@{k} fell below 95% of exhaustive: {p:.4} vs {e:.4}"
        );
    }
    let busiest4 = rows.last().expect("4-shard row").2;
    assert!(
        busiest4 * 2.0 <= single_busiest,
        "4-shard fleet's busiest chip must sense <= half the single chip's \
         macros: {busiest4:.1} vs {single_busiest:.1}"
    );

    let out = std::env::var("DIRC_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_8.json").into());
    let json = Json::obj(vec![
        ("bench", Json::str("fleet_scaling")),
        (
            "corpus",
            Json::obj(vec![
                ("docs", Json::num(n as f64)),
                ("dim", Json::num(dim as f64)),
                ("stored_mb", Json::num(db.stored_bytes() as f64 / (1 << 20) as f64)),
                ("queries", Json::num(n_queries as f64)),
            ]),
        ),
        (
            "config",
            Json::obj(vec![
                ("n_clusters", Json::num(N_CLUSTERS as f64)),
                ("nprobe", Json::num(NPROBE as f64)),
                ("cores", Json::num(cfg.cores as f64)),
            ]),
        ),
        (
            "fleets",
            Json::arr(
                rows.iter()
                    .map(|(chips, per_chip, busiest, host)| {
                        Json::obj(vec![
                            ("chips", Json::num(*chips as f64)),
                            (
                                "per_chip_macros_per_query",
                                Json::arr(per_chip.iter().map(|&m| Json::num(m)).collect()),
                            ),
                            ("busiest_macros_per_query", Json::num(*busiest)),
                            ("host_s_per_query", Json::num(*host)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "precision",
            Json::obj(vec![
                ("p_at_1", Json::num(p1)),
                ("p_at_5", Json::num(p5)),
                ("p_at_10", Json::num(p10)),
                ("exhaustive_p_at_1", Json::num(e1)),
                ("exhaustive_p_at_5", Json::num(e5)),
                ("exhaustive_p_at_10", Json::num(e10)),
            ]),
        ),
        (
            "savings",
            Json::obj(vec![(
                "busiest_ratio_4_chips",
                Json::num(single_busiest / busiest4.max(1e-9)),
            )]),
        ),
    ]);
    std::fs::write(&out, json.to_string_pretty()).expect("write bench artifact");
    println!("wrote {out}");

    b.report("fleet_scaling");
}
