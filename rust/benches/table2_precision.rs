//! Table II — retrieval precision (P@1/3/5) across the five datasets and
//! {FP32, INT8, INT4}, plus the embedding-size columns.
//!
//! Paper reference values are printed alongside; absolute numbers come
//! from synthetic stand-in corpora (see DESIGN.md substitutions) so the
//! comparison target is the *shape*: INT8 ~ FP32, INT4 slightly lower.

mod common;

use dirc_rag::bench::Table;
use dirc_rag::data::paper_datasets;
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::eval::{evaluate, PrecisionReport};
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::Prune;
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::topk::topk_from_scores;

/// Paper Table II values: (dataset, [P@1 fp32/int8/int4, P@3 ..., P@5 ...]).
const PAPER: &[(&str, [f64; 9])] = &[
    ("scifact", [0.5067, 0.5033, 0.4833, 0.2400, 0.2378, 0.2244, 0.1633, 0.1640, 0.1553]),
    ("nfcorpus", [0.4210, 0.4149, 0.3684, 0.3540, 0.3488, 0.3034, 0.3046, 0.3028, 0.2743]),
    ("trec-covid", [0.6400, 0.6200, 0.5400, 0.5667, 0.5600, 0.5533, 0.5640, 0.5520, 0.4960]),
    ("arguana", [0.2525, 0.2560, 0.2489, 0.1669, 0.1650, 0.1562, 0.1255, 0.1255, 0.1172]),
    ("scidocs", [0.2410, 0.2400, 0.2160, 0.1907, 0.1917, 0.1683, 0.1570, 0.1572, 0.1408]),
];

fn main() {
    let mut t = Table::new(&[
        "dataset", "quant", "MB", "P@1 (paper)", "P@3 (paper)", "P@5 (paper)",
    ]);

    for spec in paper_datasets() {
        let nq = common::query_cap(spec.n_queries);
        let ds = common::generate(&spec);
        let paper = PAPER.iter().find(|(n, _)| *n == spec.name).unwrap().1;

        let reports: Vec<(QuantScheme, PrecisionReport)> =
            [QuantScheme::Fp32, QuantScheme::Int8, QuantScheme::Int4]
                .into_iter()
                .map(|scheme| {
                    let rep = if scheme == QuantScheme::Fp32 {
                        evaluate(nq, &ds.qrels[..nq], |qi| {
                            let scores = dirc_rag::retrieval::score::fp_scores(
                                &ds.docs, ds.n_docs, ds.dim, ds.query(qi), Metric::Cosine);
                            topk_from_scores(&scores, 0, 5)
                        })
                    } else {
                        let db = quantize(&ds.docs, ds.n_docs, ds.dim, scheme);
                        let cfg = ChipConfig {
                            bits: scheme.bits(),
                            map_points: 60,
                            ..ChipConfig::paper_default(spec.dim, Metric::Cosine)
                        };
                        let chip = DircChip::build(cfg, &db);
                        let oracle =
                            QueryPlan::topk(5).prune(Prune::None).build().unwrap();
                        evaluate(nq, &ds.qrels[..nq], |qi| {
                            let q = quantize(ds.query(qi), 1, ds.dim, scheme);
                            chip.clean_execute(&q.values, &oracle)
                        })
                    };
                    (scheme, rep)
                })
                .collect();

        for (i, (scheme, rep)) in reports.iter().enumerate() {
            let mb = if *scheme == QuantScheme::Fp32 {
                spec.embedding_mb(32)
            } else {
                spec.embedding_mb(scheme.bits())
            };
            t.row(&[
                if i == 0 { spec.name.to_string() } else { String::new() },
                scheme.name().to_string(),
                format!("{mb:.2}"),
                format!("{:.4} ({:.4})", rep.p_at_1, paper[i]),
                format!("{:.4} ({:.4})", rep.p_at_3, paper[3 + i]),
                format!("{:.4} ({:.4})", rep.p_at_5, paper[6 + i]),
            ]);
        }

        // Shape assertions (who wins, roughly by how much).
        let fp32 = reports[0].1;
        let int8 = reports[1].1;
        let int4 = reports[2].1;
        assert!(
            (int8.p_at_1 - fp32.p_at_1).abs() <= 0.05 * fp32.p_at_1.max(0.1),
            "{}: INT8 should track FP32",
            spec.name
        );
        // Small-sample noise can flip near-equal values (the paper itself
        // has arguana INT8 P@1 > FP32); assert with tolerance.
        assert!(
            int4.p_at_1 <= int8.p_at_1 + 0.04,
            "{}: INT4 {} should not beat INT8 {} by more than noise",
            spec.name,
            int4.p_at_1,
            int8.p_at_1
        );
    }

    println!("\n=== Table II: retrieval precision, measured (paper) ===");
    t.print();
    println!("\nshape check passed: INT8 ~ FP32, INT4 <= INT8 on every dataset");
}
