//! Table III — DIRC-RAG vs RTX3090 on SciFact (single-query retrieval):
//! latency/query, energy/query, P@3.

mod common;

use dirc_rag::baseline::GpuModel;
use dirc_rag::bench::Table;
use dirc_rag::data::dataset_by_name;
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::eval::evaluate;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::topk::topk_from_scores;

fn main() {
    let spec = dataset_by_name("scifact").unwrap();
    let nq = common::query_cap(spec.n_queries);
    let ds = common::generate(&spec);

    // DIRC side: INT8 on the chip simulator, errors + detection on.
    let db = quantize(&ds.docs, ds.n_docs, ds.dim, QuantScheme::Int8);
    let cfg = ChipConfig {
        map_points: common::map_points().min(300),
        ..ChipConfig::paper_default(spec.dim, Metric::Cosine)
    };
    let chip = DircChip::build(cfg, &db);
    // Seed 3: the nonce stream the pre-plan run drew from Pcg::new(3).
    let queries: Vec<Vec<i8>> = (0..nq)
        .map(|qi| quantize(ds.query(qi), 1, ds.dim, QuantScheme::Int8).values)
        .collect();
    let outs = chip.execute_batch(&queries, &QueryPlan::topk(5).seed(3).build().unwrap());
    let lat: f64 = outs.iter().map(|o| o.stats.latency_s).sum();
    let energy: f64 = outs.iter().map(|o| o.stats.energy_j).sum();
    let dirc_rep = evaluate(nq, &ds.qrels[..nq], |qi| outs[qi].topk.clone());
    let dirc_lat = lat / nq as f64;
    let dirc_energy = energy / nq as f64;

    // GPU side: FP32 precision from exact scores; cost from the model.
    let gpu = GpuModel::default();
    let gpu_cost = gpu.per_query(ds.n_docs, ds.dim, 1.0, 1);
    let gpu_rep = evaluate(nq, &ds.qrels[..nq], |qi| {
        let scores = dirc_rag::retrieval::score::fp_scores(
            &ds.docs, ds.n_docs, ds.dim, ds.query(qi), Metric::Cosine);
        topk_from_scores(&scores, 0, 5)
    });

    let mut t = Table::new(&["", "DIRC-RAG (model/paper)", "RTX3090 (model/paper)"]);
    t.row(&["Process", "TSMC 40nm", "Samsung 8nm"]);
    t.row(&["Area", "6.18 mm^2", "628.4 mm^2"]);
    t.row(&["Embeddings", "INT8", "FP32/INT8"]);
    t.row(&["Dataset", "scifact (synthetic stand-in)", ""]);
    t.row(&[
        "Precision@3".to_string(),
        format!("{:.4} (paper 0.2378)", dirc_rep.p_at_3),
        format!("{:.4} (paper 0.2400)", gpu_rep.p_at_3),
    ]);
    t.row(&[
        "Energy/Query".to_string(),
        format!("{:.3} µJ (paper 0.46 µJ)", dirc_energy * 1e6),
        format!("{:.2} mJ (paper 86.8 mJ)", gpu_cost.energy_j * 1e3),
    ]);
    t.row(&[
        "Latency/Query".to_string(),
        format!("{:.2} µs (paper 2.77 µs)", dirc_lat * 1e6),
        format!("{:.3} ms (paper 21.7 ms)", gpu_cost.latency_s * 1e3),
    ]);
    println!("\n=== Table III: comparison with RTX3090 ===");
    t.print();

    let lat_gap = gpu_cost.latency_s / dirc_lat;
    let e_gap = gpu_cost.energy_j / dirc_energy;
    println!(
        "\ngaps: {lat_gap:.0}x latency, {e_gap:.0}x energy \
         (paper: {:.0}x, {:.0}x — our GPU model is deliberately optimistic)",
        21.7e-3 / 2.77e-6,
        86.8e-3 / 0.46e-6
    );
    assert!(lat_gap > 10.0, "DIRC must win latency by >10x");
    assert!(e_gap > 1000.0, "DIRC must win energy by >1000x");
    assert!(
        (dirc_rep.p_at_3 - gpu_rep.p_at_3).abs() < 0.03,
        "INT8 on-chip precision must track the FP32 GPU"
    );
}
