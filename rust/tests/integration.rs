//! Cross-layer integration tests: PJRT runtime x DIRC simulator x
//! coordinator. These need `make artifacts` to have run; they skip (with
//! a note) when artifacts are absent so `cargo test` stays meaningful in
//! a cold checkout.

use std::sync::Arc;

use dirc_rag::coordinator::{
    Coordinator, CoordinatorConfig, Engine, Query, ServingEngine, SimEngine,
};
use dirc_rag::data::text::{bow_batch, TextCorpus, TextParams, HASH_BUCKETS};
use dirc_rag::data::{SynthDataset, SynthParams};
use dirc_rag::dirc::chip::ChipConfig;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme, Quantized};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::Prune;
use dirc_rag::runtime::PjrtRuntime;
use dirc_rag::util::rng::Pcg;

fn runtime() -> Option<Arc<PjrtRuntime>> {
    let dir = dirc_rag::runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping integration test: artifacts not built");
        return None;
    }
    Some(Arc::new(PjrtRuntime::new(dir).expect("runtime")))
}

fn small_db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let fp = dirc_rag::retrieval::quant::random_unit_rows(n, dim, &mut rng);
    quantize(&fp, n, dim, QuantScheme::Int8)
}

fn test_chip_cfg(dim: usize) -> ChipConfig {
    ChipConfig {
        cores: 4,
        map_points: 60,
        ..ChipConfig::paper_default(dim, Metric::Cosine)
    }
}

/// The serving engine (PJRT scores + correction replay) must produce
/// *identical* rankings to the pure simulator given the same rng stream.
#[test]
fn serving_engine_matches_sim_engine_exactly() {
    let Some(rt) = runtime() else { return };
    let db = small_db(700, 512, 1);
    let sim = SimEngine::new(test_chip_cfg(512), &db);
    let srv = ServingEngine::new(test_chip_cfg(512), &db, rt).expect("serving engine");

    for qseed in 0..10u64 {
        let mut rng = Pcg::new(100 + qseed);
        let q: Vec<i8> = (0..512).map(|_| rng.int_in(-128, 127) as i8).collect();
        let plan = QueryPlan::topk(10).seed(7 + qseed).build().unwrap();
        let out_sim = sim.retrieve(&q, &plan);
        let out_srv = srv.retrieve(&q, &plan);
        let (top_sim, stats_sim) = (out_sim.topk, out_sim.stats);
        let (top_srv, stats_srv) = (out_srv.topk, out_srv.stats);
        let ids_sim: Vec<u64> = top_sim.iter().map(|d| d.doc_id).collect();
        let ids_srv: Vec<u64> = top_srv.iter().map(|d| d.doc_id).collect();
        assert_eq!(ids_sim, ids_srv, "query {qseed}");
        for (a, b) in top_sim.iter().zip(top_srv.iter()) {
            assert!((a.score - b.score).abs() < 1e-9, "query {qseed}");
        }
        assert_eq!(stats_sim.sense.flips, stats_srv.sense.flips);
    }
}

/// Clean-path equivalence: PJRT block scores == Rust reference scores for
/// every core block of a chip-sized database.
#[test]
fn pjrt_blocks_match_reference_scores() {
    let Some(rt) = runtime() else { return };
    let (n, dim) = (1000, 512);
    let db = small_db(n, dim, 2);
    let mut rng = Pcg::new(3);
    let q: Vec<i8> = (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect();

    let art = rt.manifest().best_block("mips", 250, dim).unwrap().name.clone();
    for c in 0..4 {
        let lo = c * 250;
        let hi = (lo + 250).min(n);
        let block = &db.values[lo * dim..hi * dim];
        let resident = rt.upload_db(&art, block, hi - lo, dim, None).unwrap();
        let got = rt.mips_scores(&resident, &q).unwrap();
        let want = dirc_rag::retrieval::score::mips_scores(block, hi - lo, dim, &q);
        for i in 0..(hi - lo) {
            assert_eq!(got[i] as i64, want[i], "core {c} doc {i}");
        }
    }
}

/// Full coordinator pass over token queries: every request answered, ids
/// valid, metrics consistent.
#[test]
fn coordinator_serves_token_queries() {
    let Some(rt) = runtime() else { return };
    let corpus = TextCorpus::generate(&TextParams {
        n_docs: 256,
        n_queries: 24,
        ..TextParams::default()
    });
    let dim = rt.artifact("embed_mlp_b32").unwrap().outputs[0].shape[1];
    let mut docs_fp = Vec::new();
    for chunk in corpus.docs.chunks(32) {
        let mut feats = bow_batch(chunk);
        feats.resize(32 * HASH_BUCKETS, 0.0);
        let emb = rt.embed(&feats, 32).unwrap();
        docs_fp.extend_from_slice(&emb[..chunk.len() * dim]);
    }
    let db = quantize(&docs_fp, 256, dim, QuantScheme::Int8);
    let engine = Arc::new(ServingEngine::new(test_chip_cfg(dim), &db, Arc::clone(&rt)).unwrap());
    let coord = Coordinator::start(engine, rt, CoordinatorConfig {
        workers: 2,
        ..CoordinatorConfig::default()
    });

    let mut rxs = Vec::new();
    for q in 0..24 {
        let (id, rx) = coord
            .submit(
                Query::Tokens(corpus.queries[q].clone()),
                QueryPlan::topk(5).build().unwrap(),
            )
            .unwrap();
        rxs.push((id, rx));
    }
    let mut seen = std::collections::HashSet::new();
    for (id, rx) in rxs {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.id, id);
        assert_eq!(resp.topk.len(), 5);
        assert!(resp.topk.iter().all(|d| (d.doc_id as usize) < 256));
        assert!(resp.stats.latency_s > 0.0);
        seen.insert(id);
    }
    assert_eq!(seen.len(), 24);
    let snap = coord.shutdown();
    assert_eq!(snap.served, 24);
    assert_eq!(snap.errors, 0);
}

/// Pre-embedded queries bypass the embedder and still serve.
#[test]
fn coordinator_serves_embedding_queries() {
    let Some(rt) = runtime() else { return };
    let dim = 512;
    let db = small_db(300, dim, 4);
    let engine = Arc::new(ServingEngine::new(test_chip_cfg(dim), &db, Arc::clone(&rt)).unwrap());
    let coord = Coordinator::start(engine, rt, CoordinatorConfig::default());
    let mut rng = Pcg::new(5);
    let emb: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let (_, rx) = coord
        .submit(Query::Embedding(emb), QueryPlan::topk(3).build().unwrap())
        .unwrap();
    let resp = rx.recv().unwrap();
    assert_eq!(resp.topk.len(), 3);
    assert_eq!(resp.embed_s, 0.0);
    coord.shutdown();
}

/// Retrieval quality end-to-end: the simulator engine on a calibrated
/// dataset must beat chance by a wide margin, and detection + remap must
/// hold precision near the clean reference at the nominal corner.
#[test]
fn sim_engine_preserves_precision_at_nominal_corner() {
    let params = SynthParams {
        topics: 32,
        doc_noise: 0.6,
        rels_per_query: 1,
        extra_rel_range: 1,
        query_noise: 0.5,
        confuse: 0.8,
        aniso: 1.0,
        seed: 11,
    };
    let ds = SynthDataset::generate(1500, 60, 512, &params);
    let db = quantize(&ds.docs, 1500, 512, QuantScheme::Int8);
    let chip = dirc_rag::dirc::chip::DircChip::build(test_chip_cfg(512), &db);

    let queries: Vec<Vec<i8>> = (0..60)
        .map(|qi| quantize(ds.query(qi), 1, 512, QuantScheme::Int8).values)
        .collect();
    let oracle = QueryPlan::topk(5).prune(Prune::None).build().unwrap();
    let clean = dirc_rag::eval::evaluate(60, &ds.qrels, |qi| {
        chip.clean_execute(&queries[qi], &oracle)
    });
    // Seed 13: the nonce stream the pre-plan harness drew from
    // Pcg::new(13), one nonce per query in order.
    let outs = chip.execute_batch(&queries, &QueryPlan::topk(5).seed(13).build().unwrap());
    let noisy = dirc_rag::eval::evaluate(60, &ds.qrels, |qi| outs[qi].topk.clone());
    assert!(clean.p_at_1 > 0.5, "dataset too hard: {}", clean.p_at_1);
    assert!(
        noisy.p_at_1 >= clean.p_at_1 - 0.05,
        "nominal-corner errors must not dent precision: clean {} noisy {}",
        clean.p_at_1,
        noisy.p_at_1
    );
}
