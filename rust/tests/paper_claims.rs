//! Paper-claim regression tests: every headline number/shape from the
//! paper, asserted against the models (no PJRT needed — pure simulator).
//! These are the "does the reproduction still reproduce" gate.

use dirc_rag::baseline::{CimDataflow, CimDataflowModel, GpuModel};
use dirc_rag::constants::*;
use dirc_rag::data::{paper_datasets, SynthDataset};
use dirc_rag::dirc::chip::{ChipConfig, DircChip};
use dirc_rag::dirc::variation::VariationModel;
use dirc_rag::dirc::RemapStrategy;
use dirc_rag::eval::evaluate;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::Prune;
use dirc_rag::sim::ChipSpec;
use dirc_rag::util::rng::Pcg;

/// Table I: geometry and derived figures.
#[test]
fn table1_spec_sheet() {
    let s = ChipSpec::derive();
    assert_eq!(s.total_nvm_bytes, 4 * 1024 * 1024);
    assert!((s.chip_tops - 131.0).abs() < 3.0);
    assert!((s.macro_tops_per_w - 1176.0).abs() < 25.0);
    assert!((s.retrieval_latency_s * 1e6 - 5.6).abs() < 0.6);
    assert!((s.energy_per_query_j * 1e6 - 0.956).abs() < 0.1);
    assert!((s.memory_density_mb_per_mm2 - 5.178).abs() < 0.35);
}

/// Sec IV.B: latency and energy scale linearly with database size.
#[test]
fn linear_scaling_with_db_size() {
    let dim = 512;
    let mut latencies = Vec::new();
    let mut energies = Vec::new();
    for &n in &[2048usize, 4096, 8192] {
        let mut rng = Pcg::new(1);
        let fp: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * 0.05).collect();
        let db = quantize(&fp, n, dim, QuantScheme::Int8);
        let cfg = ChipConfig { map_points: 40, ..ChipConfig::paper_default(dim, Metric::Mips) };
        let chip = DircChip::build(cfg, &db);
        let q: Vec<i8> = (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect();
        // Streaming contract: hoist the shared rng's next draw, exactly
        // as the pre-plan API consumed it.
        let plan = QueryPlan::topk(10).stream(&mut rng).build().unwrap();
        let stats = chip.execute(&q, &plan).stats;
        latencies.push(stats.latency_s);
        energies.push(stats.energy_j);
    }
    // Variable part doubles when the DB doubles (fixed overhead shrinks
    // the observed ratio below 2 but it must stay clearly super-1.5x).
    for w in latencies.windows(2) {
        let r = w[1] / w[0];
        assert!((1.5..2.2).contains(&r), "latency ratio {r}");
    }
    for w in energies.windows(2) {
        let r = w[1] / w[0];
        assert!((1.5..2.2).contains(&r), "energy ratio {r}");
    }
}

/// Sec III.B: INT4 stores twice as many embeddings as INT8.
#[test]
fn int4_doubles_capacity() {
    let i8cfg = ChipConfig::paper_default(512, Metric::Mips);
    let i4cfg = ChipConfig { bits: 4, ..ChipConfig::paper_default(512, Metric::Mips) };
    assert_eq!(i4cfg.capacity_docs(), 2 * i8cfg.capacity_docs());
}

/// Table II shape: INT8 ~ FP32; INT4 visibly but acceptably lower.
#[test]
fn table2_quantisation_shape() {
    let spec = paper_datasets().into_iter().find(|d| d.name == "scifact").unwrap();
    let nq = 120;
    let ds = SynthDataset::generate(spec.n_docs, nq, spec.dim, &spec.params);

    let fp32 = evaluate(nq, &ds.qrels[..nq], |qi| {
        let scores = dirc_rag::retrieval::score::fp_scores(
            &ds.docs, ds.n_docs, ds.dim, ds.query(qi), Metric::Cosine);
        dirc_rag::retrieval::topk::topk_from_scores(&scores, 0, 5)
    });
    let run_quant = |scheme: QuantScheme| {
        let db = quantize(&ds.docs, ds.n_docs, ds.dim, scheme);
        let cfg = ChipConfig {
            bits: scheme.bits(),
            map_points: 50,
            ..ChipConfig::paper_default(spec.dim, Metric::Cosine)
        };
        let chip = DircChip::build(cfg, &db);
        let oracle = QueryPlan::topk(5).prune(Prune::None).build().unwrap();
        evaluate(nq, &ds.qrels[..nq], |qi| {
            let q = quantize(ds.query(qi), 1, ds.dim, scheme);
            chip.clean_execute(&q.values, &oracle)
        })
    };
    let int8 = run_quant(QuantScheme::Int8);
    let int4 = run_quant(QuantScheme::Int4);

    // Paper: FP32 P@1 0.5067, INT8 0.5033 (-0.7%), INT4 0.4833 (-4.6%).
    assert!((int8.p_at_1 - fp32.p_at_1).abs() / fp32.p_at_1 < 0.03,
        "INT8 {} vs FP32 {}", int8.p_at_1, fp32.p_at_1);
    assert!(int4.p_at_1 <= int8.p_at_1 + 1e-9, "INT4 {} INT8 {}", int4.p_at_1, int8.p_at_1);
    assert!(int4.p_at_1 > fp32.p_at_1 * 0.75, "INT4 collapsed: {}", int4.p_at_1);
}

/// Fig 5a: MSB reliable, LSB spatially structured.
#[test]
fn fig5a_error_map_structure() {
    let map = VariationModel::default().extract_error_map(400, 99);
    assert_eq!(map.msb_max(), 0.0, "MSB must be 100% reliable at nominal");
    assert!(map.lsb_mean() > 1e-5);
    // Spatial structure: the best position is at least 3x better than the
    // worst (the gradient the remap exploits).
    let pos = map.positions_by_reliability();
    let best = map.lsb_at(pos[0].0, pos[0].1);
    let worst = map.lsb_at(pos[63].0, pos[63].1);
    assert!(worst > best * 3.0 || best == 0.0, "best {best} worst {worst}");
}

/// Fig 6 shape: at a stressed corner, error-aware remap recovers a large
/// fraction of the precision the naive mapping loses, and detection
/// recovers more.
#[test]
fn fig6_error_optimisation_recovers_precision() {
    let spec = paper_datasets().into_iter().find(|d| d.name == "scifact").unwrap();
    let nq = 80;
    let ds = SynthDataset::generate(spec.n_docs, nq, spec.dim, &spec.params);
    let db = quantize(&ds.docs, ds.n_docs, ds.dim, QuantScheme::Int8);

    let corner = 3.0;
    let run = |remap: RemapStrategy, detect: bool| {
        let cfg = ChipConfig {
            remap,
            detect,
            variation: VariationModel { corner, ..VariationModel::default() },
            map_points: 150,
            ..ChipConfig::paper_default(spec.dim, Metric::Cosine)
        };
        let chip = DircChip::build(cfg, &db);
        // Seed 5: the nonce stream the pre-plan run drew from
        // Pcg::new(5), one nonce per query in order.
        let queries: Vec<Vec<i8>> = (0..nq)
            .map(|qi| quantize(ds.query(qi), 1, ds.dim, QuantScheme::Int8).values)
            .collect();
        let outs = chip.execute_batch(&queries, &QueryPlan::topk(5).seed(5).build().unwrap());
        evaluate(nq, &ds.qrels[..nq], |qi| outs[qi].topk.clone())
    };

    let naive = run(RemapStrategy::Interleaved, false);
    let remap = run(RemapStrategy::ErrorAware, false);
    let full = run(RemapStrategy::ErrorAware, true);

    assert!(
        remap.p_at_1 > naive.p_at_1,
        "remap must improve precision: naive {} remap {}",
        naive.p_at_1,
        remap.p_at_1
    );
    assert!(
        full.p_at_1 >= remap.p_at_1,
        "detection must not hurt: remap {} full {}",
        remap.p_at_1,
        full.p_at_1
    );
}

/// Table III shape: DIRC beats the GPU by orders of magnitude on both
/// latency and energy for single-query retrieval.
#[test]
fn table3_gpu_comparison_shape() {
    let gpu = GpuModel::default();
    let scifact_docs = 3711;
    let g = gpu.retrieval_cost(scifact_docs, 512, 1.0, 1);
    // DIRC side from the cycle/energy models at SciFact occupancy.
    let cyc = dirc_rag::sim::cycles::CycleModel::default();
    let qc = cyc.chip_query(&[8; NUM_CORES], 8, true, &[0; NUM_CORES], 10);
    let dirc_latency = cyc.seconds(qc.total());
    assert!(dirc_latency < 3.5e-6, "{dirc_latency}");
    assert!(g.latency_s / dirc_latency > 10.0);
    assert!(g.energy_j / 0.46e-6 > 1000.0);
}

/// Sec III.B: the QS dataflow beats WS and IS on latency, energy and
/// utilisation for retrieval.
#[test]
fn dataflow_argument_holds() {
    let m = CimDataflowModel::default();
    let qs = m.cost(CimDataflow::QueryStationary, 8192, 512, 8);
    let ws = m.cost(CimDataflow::WeightStationary, 8192, 512, 8);
    let is = m.cost(CimDataflow::InputStationary, 8192, 512, 8);
    assert!(qs.latency_s < ws.latency_s && qs.latency_s < is.latency_s);
    assert!(qs.energy_j < ws.energy_j && qs.energy_j < is.energy_j);
    assert!(qs.compute_utilisation > ws.compute_utilisation);
    assert!(qs.compute_utilisation > is.compute_utilisation);
}

/// Sec III.A update path, quantified: writing the full 4 MB database is
/// a milliseconds-scale, tens-of-µJ operation — orders over the 5.6 µs /
/// 0.956 µJ query, which is exactly the trade the query-stationary
/// dataflow makes (reads cheap, writes rare). The numbers follow
/// analytically from the write model: 16.78 M MLC cells, truncated-
/// geometric expected pulses (1 - (1-y)^16)/y ≈ 1.667 at y = 0.6,
/// ~2.008 pJ and 104 ns per program+verify pulse, 16 macros x 128 cells
/// word-line-parallel.
#[test]
fn table_write_cost_for_4mb_corpus() {
    let w = dirc_rag::dirc::write::WriteModel::default();
    let exp = w.expected_pulses();
    assert!((exp - (1.0 - 0.4f64.powi(16)) / 0.6).abs() < 1e-9, "exp pulses {exp}");

    let cost = w.database_write_cost(4 << 20, NUM_CORES);
    assert_eq!(cost.cells_written, (4 << 20) * 8 / 2);
    // ~1.42 ms: 8192 serial word-line steps x 1.667 pulses x 104 ns.
    assert!((1.0e-3..2.0e-3).contains(&cost.time_s), "write time {}", cost.time_s);
    // ~56 µJ: 16.78 M cells x 1.667 pulses x 2.008 pJ.
    assert!((45e-6..70e-6).contains(&cost.energy_j), "write energy {}", cost.energy_j);
    // ~250x (two-plus orders) over the query latency — reads must
    // dominate for the QS trade to pay, which is the premise quantified.
    assert!(cost.time_s / 5.6e-6 > 100.0);
}

/// Sec III.A fallback crossover: one full-database NVM programming pass
/// costs less energy than a single SRAM-fallback query's DRAM refill
/// traffic, so native mode breaks even in under one query — and at
/// realistic online-ingest rates (a percent of the corpus per update)
/// the breakeven is a small fraction of a query.
#[test]
fn table_sram_fallback_breakeven_point() {
    let f = dirc_rag::dirc::write::SramFallbackModel::default();
    let w = dirc_rag::dirc::write::WriteModel::default();
    // Fallback per-query energy is DRAM-fetch dominated: ~85 µJ for 4 MB.
    let per_query = f.query_cost((4 << 20) * 8, NUM_CORES, 8);
    assert!((70e-6..110e-6).contains(&per_query.energy_j), "{}", per_query.energy_j);
    // Breakeven ≈ 0.66 queries (56 µJ write / 85 µJ refill).
    let be = f.breakeven_queries(&w, 4 << 20, NUM_CORES);
    assert!((0.4..1.0).contains(&be), "breakeven {be}");
    // Online ingest rewriting 1% of the corpus amortises in well under
    // one query — the dynamic-corpus regime is firmly native-mode.
    let be_1pct = f.breakeven_queries_at_rate(&w, 4 << 20, NUM_CORES, 0.01);
    assert!(be_1pct < 0.05, "1% update breakeven {be_1pct}");
    assert!(be_1pct > 0.0);
}

/// Table II size columns: dataset INT8 embeddings all fit the 4 MB chip
/// (after the paper's documented sampling).
#[test]
fn datasets_fit_chip() {
    for d in paper_datasets() {
        assert!(d.embedding_mb(8) < 4.0, "{}", d.name);
        let full_corpus_mb = d.embedding_mb(8) * d.sample_factor as f64;
        if d.sample_factor > 1 {
            assert!(full_corpus_mb > 4.0, "{} would not need sampling", d.name);
        }
    }
}
