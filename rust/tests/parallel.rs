//! Golden-vector tests for the parallel sharded query path: the parallel
//! per-core execution (`query_on`, `sense_pass_on`, `query_batch`) must be
//! **bit-identical** to the serial walk — same doc ids, same score bits,
//! same sense statistics, same cycle/energy accounting — across seeds,
//! core counts, metrics, thread counts and tie-heavy score distributions.

use std::sync::Arc;

use dirc_rag::coordinator::{Engine, SimEngine};
use dirc_rag::dirc::chip::{ChipConfig, DircChip, QueryStats};
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::{norm_i8, Metric};
use dirc_rag::retrieval::Prune;
use dirc_rag::util::pool::ThreadPool;
use dirc_rag::util::rng::Pcg;

fn assert_stats_identical(a: &QueryStats, b: &QueryStats, ctx: &str) {
    assert_eq!(a.sense, b.sense, "{ctx}: sense stats");
    assert_eq!(a.cycles, b.cycles, "{ctx}: cycles");
    assert_eq!(a.work_cycles, b.work_cycles, "{ctx}: work cycles");
    assert_eq!(a.macros_sensed, b.macros_sensed, "{ctx}: macros sensed");
    assert_eq!(a.macros_skipped, b.macros_skipped, "{ctx}: macros skipped");
    assert_eq!(a.docs_scored, b.docs_scored, "{ctx}: docs_scored");
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{ctx}: latency bits");
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{ctx}: energy bits");
}

fn build_chip(n: usize, dim: usize, cores: usize, seed: u64, metric: Metric) -> DircChip {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let cfg = ChipConfig {
        cores,
        map_points: 40,
        ..ChipConfig::paper_default(dim, metric)
    };
    DircChip::build(cfg, &db)
}

/// A database whose quantised values come from {-1, 0, 1}, so integer MIPS
/// scores collide constantly — the distribution that stresses top-k
/// tie-breaking across the merge.
fn tie_heavy_db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let values: Vec<i8> = (0..n * dim).map(|_| rng.int_in(-1, 1) as i8).collect();
    let norms: Vec<f32> = (0..n)
        .map(|i| norm_i8(&values[i * dim..(i + 1) * dim]) as f32)
        .collect();
    Quantized { scheme: QuantScheme::Int8, n, dim, values, scale: 1.0, norms }
}

#[test]
fn parallel_query_bit_identical_across_seeds_and_core_counts() {
    let dim = 128;
    for &cores in &[1usize, 2, 4, 8] {
        for metric in [Metric::Mips, Metric::Cosine] {
            let chip = build_chip(400, dim, cores, 11, metric);
            for qseed in 0..3u64 {
                let mut qrng = Pcg::new(900 + qseed);
                let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect();
                let mut r_serial = Pcg::new(qseed);
                let (top_s, stats_s) = chip.query(&q, 10, &mut r_serial);
                for &threads in &[2usize, 4, 8] {
                    let mut r_par = Pcg::new(qseed);
                    let (top_p, stats_p) = chip.query_on(&q, 10, &mut r_par, threads);
                    let ctx = format!(
                        "cores={cores} metric={metric:?} qseed={qseed} threads={threads}"
                    );
                    assert_eq!(top_s, top_p, "{ctx}: ranking");
                    for (a, b) in top_s.iter().zip(top_p.iter()) {
                        assert_eq!(a.score.to_bits(), b.score.to_bits(), "{ctx}: score bits");
                    }
                    assert_stats_identical(&stats_s, &stats_p, &ctx);
                    // Both paths must leave the caller rng in the same
                    // position (one nonce drawn per query).
                    assert_eq!(r_serial.clone().next_u64(), r_par.clone().next_u64(), "{ctx}");
                }
            }
        }
    }
}

#[test]
fn parallel_query_bit_identical_on_tie_heavy_scores() {
    let (n, dim) = (512, 128);
    let db = tie_heavy_db(n, dim, 21);
    for &cores in &[2usize, 4, 8] {
        let cfg = ChipConfig {
            cores,
            map_points: 40,
            ..ChipConfig::paper_default(dim, Metric::Mips)
        };
        let chip = DircChip::build(cfg, &db);
        for qseed in 0..4u64 {
            // Tiny-valued queries -> massively duplicated integer scores.
            let mut qrng = Pcg::new(300 + qseed);
            let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-1, 1) as i8).collect();
            let mut r1 = Pcg::new(qseed);
            let mut r2 = Pcg::new(qseed);
            let (top_s, stats_s) = chip.query(&q, 16, &mut r1);
            let (top_p, stats_p) = chip.query_on(&q, 16, &mut r2, 4);
            let ctx = format!("tie-heavy cores={cores} qseed={qseed}");
            assert_eq!(top_s, top_p, "{ctx}");
            assert_stats_identical(&stats_s, &stats_p, &ctx);
            // Ties really are present, and broken by lower doc id.
            for w in top_s.windows(2) {
                if w[0].score == w[1].score {
                    assert!(w[0].doc_id < w[1].doc_id, "{ctx}: tie-break order");
                }
            }
        }
    }
}

#[test]
fn sense_pass_parallel_matches_serial_flips() {
    let chip = build_chip(600, 128, 4, 31, Metric::Cosine);
    for qseed in 0..3u64 {
        let mut r1 = Pcg::new(qseed);
        let mut r2 = Pcg::new(qseed);
        let (flips_s, stats_s) = chip.sense_pass(10, &mut r1);
        let (flips_p, stats_p) = chip.sense_pass_on(10, &mut r2, 4);
        assert_eq!(flips_s, flips_p, "qseed={qseed}: per-core flips");
        assert_stats_identical(&stats_s, &stats_p, &format!("sense qseed={qseed}"));
    }
}

#[test]
fn query_batch_matches_serial_query_stream() {
    let chip = Arc::new(build_chip(400, 128, 4, 41, Metric::Mips));
    let pool = ThreadPool::new(4);
    let mut qrng = Pcg::new(5);
    let queries: Vec<Vec<i8>> = (0..11)
        .map(|_| (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let mut r_serial = Pcg::new(123);
    let mut r_batch = Pcg::new(123);
    let want: Vec<_> = queries.iter().map(|q| chip.query(q, 10, &mut r_serial)).collect();
    let got = DircChip::query_batch(&chip, &pool, &queries, 10, &mut r_batch);
    assert_eq!(got.len(), want.len());
    for (qi, ((gt, gs), (wt, ws))) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(gt, wt, "query {qi}: ranking");
        assert_stats_identical(gs, ws, &format!("batch query {qi}"));
    }
    // Both paths consumed the same nonce stream.
    assert_eq!(r_serial.next_u64(), r_batch.next_u64());
    assert_eq!(pool.panicked(), 0);
}

#[test]
fn query_batch_empty_and_single() {
    let chip = Arc::new(build_chip(200, 128, 2, 51, Metric::Mips));
    let pool = ThreadPool::new(2);
    let mut rng = Pcg::new(1);
    assert!(DircChip::query_batch(&chip, &pool, &[], 5, &mut rng).is_empty());
    let q: Vec<i8> = (0..128).map(|_| rng.int_in(-128, 127) as i8).collect();
    let mut r1 = Pcg::new(2);
    let mut r2 = Pcg::new(2);
    let want = chip.query(&q, 5, &mut r1);
    let got = DircChip::query_batch(&chip, &pool, std::slice::from_ref(&q), 5, &mut r2);
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].0, want.0);
}

/// Mutate-then-query schedule: with online corpus mutations interleaved
/// between query rounds, the parallel per-core execution must stay
/// bit-identical to the serial walk. Two identical chips receive the
/// same mutation stream (adds, in-place updates, tombstones — same
/// payloads, same write rng); after every round the serial path on one
/// chip and the threaded paths on the other must agree bit-for-bit.
#[test]
fn mutate_then_query_schedule_bit_identical() {
    use dirc_rag::dirc::chip::DocPayload;

    let (n, dim) = (400, 128);
    let mut chip_s = build_chip(n, dim, 4, 71, Metric::Cosine);
    let mut chip_p = build_chip(n, dim, 4, 71, Metric::Cosine);

    // Fresh embeddings to ingest, in the same quantised space.
    let mut erng = Pcg::new(72);
    let extra_fp = random_unit_rows(24, dim, &mut erng);
    let extra = quantize(&extra_fp, 24, dim, QuantScheme::Int8);
    let payload = |i: usize| DocPayload {
        values: extra.row(i).to_vec(),
        norm: extra.norms[i],
    };

    let mut w_s = Pcg::new(73);
    let mut w_p = Pcg::new(73);
    let mut next_extra = 0usize;

    for round in 0..3usize {
        // Queries on the current corpus: serial vs threaded, same seeds.
        for qseed in 0..2u64 {
            let mut qrng = Pcg::new(700 + round as u64 * 10 + qseed);
            let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect();
            let mut r1 = Pcg::new(round as u64 * 100 + qseed);
            let mut r2 = Pcg::new(round as u64 * 100 + qseed);
            let (top_s, stats_s) = chip_s.query(&q, 10, &mut r1);
            let (top_p, stats_p) = chip_p.query_on(&q, 10, &mut r2, 4);
            let ctx = format!("round {round} qseed {qseed}");
            assert_eq!(top_s, top_p, "{ctx}: ranking");
            assert_stats_identical(&stats_s, &stats_p, &ctx);
        }

        // Mutation burst, applied identically to both chips.
        let adds: Vec<DocPayload> = (0..4).map(|i| payload(next_extra + i)).collect();
        next_extra += 4;
        let (ids_s, st_s) = chip_s.add_docs(&adds, &mut w_s).expect("add");
        let (ids_p, st_p) = chip_p.add_docs(&adds, &mut w_p).expect("add");
        assert_eq!(ids_s, ids_p, "round {round}: assigned ids diverged");
        assert_eq!(st_s.write_pulses, st_p.write_pulses, "round {round}: write pulses");

        let upd: Vec<(u64, DocPayload)> = (0..3)
            .map(|i| ((round * 29 + i * 11) as u64 % n as u64, payload(next_extra + i)))
            .collect();
        next_extra += 3;
        let us = chip_s.update_docs(&upd, &mut w_s).expect("update");
        let up = chip_p.update_docs(&upd, &mut w_p).expect("update");
        assert_eq!(us.write_pulses, up.write_pulses);
        assert_eq!(us.docs_updated, up.docs_updated);

        let dels = [(round * 37 + 5) as u64 % n as u64];
        let ds_ = chip_s.delete_docs(&dels);
        let dp_ = chip_p.delete_docs(&dels);
        assert_eq!(ds_.docs_deleted, dp_.docs_deleted);
        assert_eq!(chip_s.n_docs(), chip_p.n_docs(), "round {round}: corpus size");
    }

    // Final corpus: the pooled queries x cores batch matrix must also
    // match a serial query stream bit-for-bit.
    let chip_p = Arc::new(chip_p);
    let pool = ThreadPool::new(4);
    let mut qrng = Pcg::new(800);
    let queries: Vec<Vec<i8>> = (0..6)
        .map(|_| (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let mut r_serial = Pcg::new(901);
    let mut r_batch = Pcg::new(901);
    let want: Vec<_> = queries.iter().map(|q| chip_s.query(q, 10, &mut r_serial)).collect();
    let got = DircChip::query_batch(&chip_p, &pool, &queries, 10, &mut r_batch);
    for (qi, ((gt, gs), (wt, ws))) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(gt, wt, "post-churn batch query {qi}");
        assert_stats_identical(gs, ws, &format!("post-churn batch query {qi}"));
    }
    assert_eq!(pool.panicked(), 0);
}

fn build_pruned_chip(db: &Quantized, cores: usize, n_clusters: usize) -> DircChip {
    let cfg = ChipConfig {
        cores,
        map_points: 40,
        cluster: ClusterPolicy { n_clusters, nprobe: 2, kmeans_iters: 6 },
        ..ChipConfig::paper_default(db.dim, Metric::Mips)
    };
    DircChip::build(cfg, db)
}

/// With pruning enabled, serial `query_opt` and the pooled
/// queries × cores matrix (`query_batch_opt`) must stay bit-identical —
/// across policies, including on tie-heavy scores where the skipped-core
/// merge could silently reorder duplicates.
#[test]
fn pruned_query_batch_bit_identical_including_ties() {
    let (n, dim) = (512, 128);
    for (label, db) in [
        ("unit-rows", {
            let mut rng = Pcg::new(81);
            let fp = random_unit_rows(n, dim, &mut rng);
            quantize(&fp, n, dim, QuantScheme::Int8)
        }),
        ("tie-heavy", tie_heavy_db(n, dim, 82)),
    ] {
        let chip = Arc::new(build_pruned_chip(&db, 4, 8));
        let pool = ThreadPool::new(4);
        let mut qrng = Pcg::new(83);
        let queries: Vec<Vec<i8>> = (0..8)
            .map(|_| (0..dim).map(|_| qrng.int_in(-3, 3) as i8).collect())
            .collect();
        for prune in [Prune::Default, Prune::Probe(1), Prune::Probe(8), Prune::None] {
            let mut r_serial = Pcg::new(84);
            let mut r_batch = Pcg::new(84);
            let want: Vec<_> = queries
                .iter()
                .map(|q| chip.query_opt(q, 12, prune, &mut r_serial, 1))
                .collect();
            let got =
                DircChip::query_batch_opt(&chip, &pool, &queries, 12, prune, &mut r_batch);
            assert_eq!(got.len(), want.len());
            for (qi, ((gt, gs), (wt, ws))) in got.iter().zip(want.iter()).enumerate() {
                let ctx = format!("{label} {prune:?} query {qi}");
                assert_eq!(gt, wt, "{ctx}: ranking");
                for (a, b) in gt.iter().zip(wt.iter()) {
                    assert_eq!(a.score.to_bits(), b.score.to_bits(), "{ctx}: score bits");
                }
                assert_stats_identical(gs, ws, &ctx);
            }
            assert_eq!(r_serial.next_u64(), r_batch.next_u64(), "{label} {prune:?}: rng");
        }
        assert_eq!(pool.panicked(), 0);
    }
}

/// Mutate-then-query interleaving with pruning live: after every
/// add/update/delete round the pruned serial path and the pruned pooled
/// batch path agree bit-for-bit (cluster routing and hosted-cluster
/// bitsets are part of the deterministic state both chips share).
#[test]
fn pruned_mutate_then_query_schedule_bit_identical() {
    use dirc_rag::dirc::chip::DocPayload;

    let (n, dim) = (400, 128);
    let mut rng = Pcg::new(91);
    let fp = random_unit_rows(n, dim, &mut rng);
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let mut chip_s = build_pruned_chip(&db, 4, 8);
    let mut chip_p = build_pruned_chip(&db, 4, 8);

    let mut erng = Pcg::new(92);
    let extra_fp = random_unit_rows(18, dim, &mut erng);
    let extra = quantize(&extra_fp, 18, dim, QuantScheme::Int8);
    let payload =
        |i: usize| DocPayload { values: extra.row(i).to_vec(), norm: extra.norms[i] };

    let mut w_s = Pcg::new(93);
    let mut w_p = Pcg::new(93);
    let mut next_extra = 0usize;

    for round in 0..3usize {
        for prune in [Prune::Default, Prune::Probe(5)] {
            let mut qrng = Pcg::new(940 + round as u64);
            let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect();
            let mut r1 = Pcg::new(round as u64 * 31 + 7);
            let mut r2 = Pcg::new(round as u64 * 31 + 7);
            let (top_s, stats_s) = chip_s.query_opt(&q, 10, prune, &mut r1, 1);
            let (top_p, stats_p) = chip_p.query_opt(&q, 10, prune, &mut r2, 4);
            let ctx = format!("round {round} {prune:?}");
            assert_eq!(top_s, top_p, "{ctx}: ranking");
            assert_stats_identical(&stats_s, &stats_p, &ctx);
        }

        let adds: Vec<DocPayload> = (0..4).map(|i| payload(next_extra + i)).collect();
        next_extra += 4;
        let (ids_s, _) = chip_s.add_docs(&adds, &mut w_s).expect("add");
        let (ids_p, _) = chip_p.add_docs(&adds, &mut w_p).expect("add");
        assert_eq!(ids_s, ids_p, "round {round}: assigned ids diverged");

        let upd: Vec<(u64, DocPayload)> = (0..2)
            .map(|i| ((round * 29 + i * 11) as u64 % n as u64, payload(next_extra + i)))
            .collect();
        next_extra += 2;
        chip_s.update_docs(&upd, &mut w_s).expect("update");
        chip_p.update_docs(&upd, &mut w_p).expect("update");

        let dels = [(round * 37 + 5) as u64 % n as u64];
        chip_s.delete_docs(&dels);
        chip_p.delete_docs(&dels);
        assert_eq!(chip_s.n_docs(), chip_p.n_docs(), "round {round}: corpus size");
    }

    // Post-churn: pooled batch matrix vs serial stream, pruned.
    let chip_p = Arc::new(chip_p);
    let pool = ThreadPool::new(4);
    let mut qrng = Pcg::new(95);
    let queries: Vec<Vec<i8>> = (0..5)
        .map(|_| (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let mut r_serial = Pcg::new(96);
    let mut r_batch = Pcg::new(96);
    let want: Vec<_> = queries
        .iter()
        .map(|q| chip_s.query_opt(q, 10, Prune::Default, &mut r_serial, 1))
        .collect();
    let got =
        DircChip::query_batch_opt(&chip_p, &pool, &queries, 10, Prune::Default, &mut r_batch);
    for (qi, ((gt, gs), (wt, ws))) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(gt, wt, "post-churn pruned batch query {qi}");
        assert_stats_identical(gs, ws, &format!("post-churn pruned batch query {qi}"));
    }
    assert_eq!(pool.panicked(), 0);
}

#[test]
fn pooled_sim_engine_end_to_end_identical() {
    let mut rng = Pcg::new(61);
    let fp = random_unit_rows(384, 128, &mut rng);
    let db = quantize(&fp, 384, 128, QuantScheme::Int8);
    let cfg = || ChipConfig {
        cores: 4,
        map_points: 40,
        ..ChipConfig::paper_default(128, Metric::Cosine)
    };
    let serial = SimEngine::new(cfg(), &db);
    let pool = Arc::new(ThreadPool::new(4));
    let pooled = SimEngine::with_pool(cfg(), &db, Some(Arc::clone(&pool)));

    let mut qrng = Pcg::new(7);
    let queries: Vec<Vec<i8>> = (0..6)
        .map(|_| (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();

    // Single-query path.
    for (qi, q) in queries.iter().enumerate() {
        let mut r1 = Pcg::new(qi as u64);
        let mut r2 = Pcg::new(qi as u64);
        let (t1, s1) = serial.retrieve(q, 5, &mut r1);
        let (t2, s2) = pooled.retrieve(q, 5, &mut r2);
        assert_eq!(t1, t2, "query {qi}");
        assert_stats_identical(&s1, &s2, &format!("engine query {qi}"));
    }

    // Batch path vs the default serial stream.
    let mut r1 = Pcg::new(99);
    let mut r2 = Pcg::new(99);
    let want = Engine::retrieve_batch(&serial, &queries, 5, &mut r1);
    let got = pooled.retrieve_batch(&queries, 5, &mut r2);
    for (qi, ((gt, gs), (wt, ws))) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(gt, wt, "batch query {qi}");
        assert_stats_identical(gs, ws, &format!("engine batch query {qi}"));
    }
}
