//! Golden-vector tests for the parallel sharded execution of
//! `QueryPlan`s: pooled execution ([`Exec::Pool`]) must be
//! **bit-identical** to the serial walk ([`Exec::Serial`]) — same doc
//! ids, same score bits, same sense statistics, same cycle/energy
//! accounting — across seeds, core counts, pool widths, metrics,
//! tie-heavy score distributions, pruning policies and
//! mutate-then-query schedules. (Old-API equivalence of the plan paths
//! themselves lives in `rust/tests/plan_api.rs`.)

use std::sync::Arc;

use dirc_rag::coordinator::{Engine, SimEngine};
use dirc_rag::dirc::chip::{ChipConfig, DircChip, QueryStats};
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::{Exec, QueryPlan};
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::{norm_i8, Metric};
use dirc_rag::retrieval::Prune;
use dirc_rag::util::pool::ThreadPool;
use dirc_rag::util::rng::Pcg;

fn assert_stats_identical(a: &QueryStats, b: &QueryStats, ctx: &str) {
    assert_eq!(a.sense, b.sense, "{ctx}: sense stats");
    assert_eq!(a.cycles, b.cycles, "{ctx}: cycles");
    assert_eq!(a.work_cycles, b.work_cycles, "{ctx}: work cycles");
    assert_eq!(a.macros_sensed, b.macros_sensed, "{ctx}: macros sensed");
    assert_eq!(a.macros_skipped, b.macros_skipped, "{ctx}: macros skipped");
    assert_eq!(a.docs_scored, b.docs_scored, "{ctx}: docs_scored");
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{ctx}: latency bits");
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{ctx}: energy bits");
}

fn build_chip(n: usize, dim: usize, cores: usize, seed: u64, metric: Metric) -> DircChip {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let cfg = ChipConfig {
        cores,
        map_points: 40,
        ..ChipConfig::paper_default(dim, metric)
    };
    DircChip::build(cfg, &db)
}

/// A database whose quantised values come from {-1, 0, 1}, so integer MIPS
/// scores collide constantly — the distribution that stresses top-k
/// tie-breaking across the merge.
fn tie_heavy_db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let values: Vec<i8> = (0..n * dim).map(|_| rng.int_in(-1, 1) as i8).collect();
    let norms: Vec<f32> = (0..n)
        .map(|i| norm_i8(&values[i * dim..(i + 1) * dim]) as f32)
        .collect();
    Quantized { scheme: QuantScheme::Int8, n, dim, values, scale: 1.0, norms }
}

#[test]
fn pooled_execute_bit_identical_across_seeds_and_core_counts() {
    let dim = 128;
    let pools: Vec<Arc<ThreadPool>> =
        [2usize, 4, 8].iter().map(|&t| Arc::new(ThreadPool::new(t))).collect();
    for &cores in &[1usize, 2, 4, 8] {
        for metric in [Metric::Mips, Metric::Cosine] {
            let chip = build_chip(400, dim, cores, 11, metric);
            for qseed in 0..3u64 {
                let mut qrng = Pcg::new(900 + qseed);
                let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect();
                let base = QueryPlan::topk(10).seed(qseed).build().unwrap();
                let serial = chip.execute(&q, &base.with_exec(Exec::Serial));
                for pool in &pools {
                    let pooled =
                        chip.execute(&q, &base.with_exec(Exec::Pool(Arc::clone(pool))));
                    let ctx = format!(
                        "cores={cores} metric={metric:?} qseed={qseed} threads={}",
                        pool.threads()
                    );
                    assert_eq!(serial.topk, pooled.topk, "{ctx}: ranking");
                    for (a, b) in serial.topk.iter().zip(pooled.topk.iter()) {
                        assert_eq!(a.score.to_bits(), b.score.to_bits(), "{ctx}: score bits");
                    }
                    assert_stats_identical(&serial.stats, &pooled.stats, &ctx);
                    assert_eq!(pool.panicked(), 0, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn pooled_execute_bit_identical_on_tie_heavy_scores() {
    let (n, dim) = (512, 128);
    let db = tie_heavy_db(n, dim, 21);
    let pool = Arc::new(ThreadPool::new(4));
    for &cores in &[2usize, 4, 8] {
        let cfg = ChipConfig {
            cores,
            map_points: 40,
            ..ChipConfig::paper_default(dim, Metric::Mips)
        };
        let chip = DircChip::build(cfg, &db);
        for qseed in 0..4u64 {
            // Tiny-valued queries -> massively duplicated integer scores.
            let mut qrng = Pcg::new(300 + qseed);
            let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-1, 1) as i8).collect();
            let base = QueryPlan::topk(16).seed(qseed).build().unwrap();
            let serial = chip.execute(&q, &base.with_exec(Exec::Serial));
            let pooled = chip.execute(&q, &base.with_exec(Exec::Pool(Arc::clone(&pool))));
            let ctx = format!("tie-heavy cores={cores} qseed={qseed}");
            assert_eq!(serial.topk, pooled.topk, "{ctx}");
            assert_stats_identical(&serial.stats, &pooled.stats, &ctx);
            // Ties really are present, and broken by lower doc id.
            for w in serial.topk.windows(2) {
                if w[0].score == w[1].score {
                    assert!(w[0].doc_id < w[1].doc_id, "{ctx}: tie-break order");
                }
            }
        }
    }
    assert_eq!(pool.panicked(), 0);
}

#[test]
fn pooled_sense_execute_matches_serial_flips() {
    let chip = build_chip(600, 128, 4, 31, Metric::Cosine);
    let pool = Arc::new(ThreadPool::new(4));
    for qseed in 0..3u64 {
        let q: Vec<i8> = {
            let mut qrng = Pcg::new(40 + qseed);
            (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect()
        };
        let base = QueryPlan::topk(10).seed(qseed).build().unwrap();
        let serial = chip.sense_execute(&q, &base.with_exec(Exec::Serial));
        let pooled = chip.sense_execute(&q, &base.with_exec(Exec::Pool(Arc::clone(&pool))));
        assert_eq!(serial.flips, pooled.flips, "qseed={qseed}: per-core flips");
        assert_stats_identical(&serial.stats, &pooled.stats, &format!("sense qseed={qseed}"));
    }
    assert_eq!(pool.panicked(), 0);
}

#[test]
fn execute_batch_matches_serial_query_stream() {
    let chip = build_chip(400, 128, 4, 41, Metric::Mips);
    let pool = Arc::new(ThreadPool::new(4));
    let mut qrng = Pcg::new(5);
    let queries: Vec<Vec<i8>> = (0..11)
        .map(|_| (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let plan = QueryPlan::topk(10).seed(123).build().unwrap();
    // Serial stream: one execute per query over the plan's nonce stream.
    let want: Vec<_> = queries
        .iter()
        .zip(plan.nonces(queries.len()))
        .map(|(q, nonce)| chip.execute(q, &plan.with_nonce(nonce)))
        .collect();
    let got = chip.execute_batch(&queries, &plan.with_exec(Exec::Pool(Arc::clone(&pool))));
    assert_eq!(got.len(), want.len());
    for (qi, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.topk, w.topk, "query {qi}: ranking");
        assert_stats_identical(&g.stats, &w.stats, &format!("batch query {qi}"));
    }
    assert_eq!(pool.panicked(), 0);
}

#[test]
fn execute_batch_empty_and_single() {
    let chip = build_chip(200, 128, 2, 51, Metric::Mips);
    let pool = Arc::new(ThreadPool::new(2));
    let plan = QueryPlan::topk(5).seed(2).pool(Arc::clone(&pool)).build().unwrap();
    assert!(chip.execute_batch(&[], &plan).is_empty());
    let mut qrng = Pcg::new(1);
    let q: Vec<i8> = (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect();
    let want = chip.execute(&q, &QueryPlan::topk(5).seed(2).build().unwrap());
    let got = chip.execute_batch(std::slice::from_ref(&q), &plan);
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].topk, want.topk);
    assert_eq!(pool.panicked(), 0);
}

/// Mutate-then-query schedule: with online corpus mutations interleaved
/// between query rounds, pooled plan execution must stay bit-identical
/// to the serial walk. Two identical chips receive the same mutation
/// stream (adds, in-place updates, tombstones — same payloads, same
/// write rng); after every round the serial plan on one chip and the
/// pooled plan on the other must agree bit-for-bit.
#[test]
fn mutate_then_query_schedule_bit_identical() {
    use dirc_rag::dirc::chip::DocPayload;

    let (n, dim) = (400, 128);
    let mut chip_s = build_chip(n, dim, 4, 71, Metric::Cosine);
    let mut chip_p = build_chip(n, dim, 4, 71, Metric::Cosine);
    let pool = Arc::new(ThreadPool::new(4));

    // Fresh embeddings to ingest, in the same quantised space.
    let mut erng = Pcg::new(72);
    let extra_fp = random_unit_rows(24, dim, &mut erng);
    let extra = quantize(&extra_fp, 24, dim, QuantScheme::Int8);
    let payload = |i: usize| DocPayload {
        values: extra.row(i).to_vec(),
        norm: extra.norms[i],
    };

    let mut w_s = Pcg::new(73);
    let mut w_p = Pcg::new(73);
    let mut next_extra = 0usize;

    for round in 0..3usize {
        // Queries on the current corpus: serial vs pooled, same plans.
        for qseed in 0..2u64 {
            let mut qrng = Pcg::new(700 + round as u64 * 10 + qseed);
            let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect();
            let base =
                QueryPlan::topk(10).seed(round as u64 * 100 + qseed).build().unwrap();
            let s = chip_s.execute(&q, &base.with_exec(Exec::Serial));
            let p = chip_p.execute(&q, &base.with_exec(Exec::Pool(Arc::clone(&pool))));
            let ctx = format!("round {round} qseed {qseed}");
            assert_eq!(s.topk, p.topk, "{ctx}: ranking");
            assert_stats_identical(&s.stats, &p.stats, &ctx);
        }

        // Mutation burst, applied identically to both chips.
        let adds: Vec<DocPayload> = (0..4).map(|i| payload(next_extra + i)).collect();
        next_extra += 4;
        let (ids_s, st_s) = chip_s.add_docs(&adds, &mut w_s).expect("add");
        let (ids_p, st_p) = chip_p.add_docs(&adds, &mut w_p).expect("add");
        assert_eq!(ids_s, ids_p, "round {round}: assigned ids diverged");
        assert_eq!(st_s.write_pulses, st_p.write_pulses, "round {round}: write pulses");

        let upd: Vec<(u64, DocPayload)> = (0..3)
            .map(|i| ((round * 29 + i * 11) as u64 % n as u64, payload(next_extra + i)))
            .collect();
        next_extra += 3;
        let us = chip_s.update_docs(&upd, &mut w_s).expect("update");
        let up = chip_p.update_docs(&upd, &mut w_p).expect("update");
        assert_eq!(us.write_pulses, up.write_pulses);
        assert_eq!(us.docs_updated, up.docs_updated);

        let dels = [(round * 37 + 5) as u64 % n as u64];
        let ds_ = chip_s.delete_docs(&dels);
        let dp_ = chip_p.delete_docs(&dels);
        assert_eq!(ds_.docs_deleted, dp_.docs_deleted);
        assert_eq!(chip_s.n_docs(), chip_p.n_docs(), "round {round}: corpus size");
    }

    // Final corpus: the pooled queries x cores batch matrix must also
    // match the serial batch bit-for-bit.
    let mut qrng = Pcg::new(800);
    let queries: Vec<Vec<i8>> = (0..6)
        .map(|_| (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let plan = QueryPlan::topk(10).seed(901).build().unwrap();
    let want = chip_s.execute_batch(&queries, &plan.with_exec(Exec::Serial));
    let got = chip_p.execute_batch(&queries, &plan.with_exec(Exec::Pool(Arc::clone(&pool))));
    for (qi, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.topk, w.topk, "post-churn batch query {qi}");
        assert_stats_identical(&g.stats, &w.stats, &format!("post-churn batch query {qi}"));
    }
    assert_eq!(pool.panicked(), 0);
}

fn build_pruned_chip(db: &Quantized, cores: usize, n_clusters: usize) -> DircChip {
    let cfg = ChipConfig {
        cores,
        map_points: 40,
        cluster: ClusterPolicy { n_clusters, nprobe: 2, kmeans_iters: 6 },
        ..ChipConfig::paper_default(db.dim, Metric::Mips)
    };
    DircChip::build(cfg, db)
}

/// With pruning enabled, the serial plan and the pooled queries × cores
/// matrix must stay bit-identical — across policies, including on
/// tie-heavy scores where the skipped-core merge could silently reorder
/// duplicates.
#[test]
fn pruned_execute_batch_bit_identical_including_ties() {
    let (n, dim) = (512, 128);
    for (label, db) in [
        ("unit-rows", {
            let mut rng = Pcg::new(81);
            let fp = random_unit_rows(n, dim, &mut rng);
            quantize(&fp, n, dim, QuantScheme::Int8)
        }),
        ("tie-heavy", tie_heavy_db(n, dim, 82)),
    ] {
        let chip = build_pruned_chip(&db, 4, 8);
        let pool = Arc::new(ThreadPool::new(4));
        let mut qrng = Pcg::new(83);
        let queries: Vec<Vec<i8>> = (0..8)
            .map(|_| (0..dim).map(|_| qrng.int_in(-3, 3) as i8).collect())
            .collect();
        for prune in [Prune::Default, Prune::Probe(1), Prune::Probe(8), Prune::None] {
            let plan = QueryPlan::topk(12).seed(84).prune(prune).build().unwrap();
            let want = chip.execute_batch(&queries, &plan.with_exec(Exec::Serial));
            let got =
                chip.execute_batch(&queries, &plan.with_exec(Exec::Pool(Arc::clone(&pool))));
            assert_eq!(got.len(), want.len());
            for (qi, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                let ctx = format!("{label} {prune:?} query {qi}");
                assert_eq!(g.topk, w.topk, "{ctx}: ranking");
                for (a, b) in g.topk.iter().zip(w.topk.iter()) {
                    assert_eq!(a.score.to_bits(), b.score.to_bits(), "{ctx}: score bits");
                }
                assert_stats_identical(&g.stats, &w.stats, &ctx);
            }
        }
        assert_eq!(pool.panicked(), 0);
    }
}

/// Mutate-then-query interleaving with pruning live: after every
/// add/update/delete round the pruned serial plan and the pruned pooled
/// plan agree bit-for-bit (cluster routing and hosted-cluster bitsets
/// are part of the deterministic state both chips share).
#[test]
fn pruned_mutate_then_query_schedule_bit_identical() {
    use dirc_rag::dirc::chip::DocPayload;

    let (n, dim) = (400, 128);
    let mut rng = Pcg::new(91);
    let fp = random_unit_rows(n, dim, &mut rng);
    let db = quantize(&fp, n, dim, QuantScheme::Int8);
    let mut chip_s = build_pruned_chip(&db, 4, 8);
    let mut chip_p = build_pruned_chip(&db, 4, 8);
    let pool = Arc::new(ThreadPool::new(4));

    let mut erng = Pcg::new(92);
    let extra_fp = random_unit_rows(18, dim, &mut erng);
    let extra = quantize(&extra_fp, 18, dim, QuantScheme::Int8);
    let payload =
        |i: usize| DocPayload { values: extra.row(i).to_vec(), norm: extra.norms[i] };

    let mut w_s = Pcg::new(93);
    let mut w_p = Pcg::new(93);
    let mut next_extra = 0usize;

    for round in 0..3usize {
        for prune in [Prune::Default, Prune::Probe(5)] {
            let mut qrng = Pcg::new(940 + round as u64);
            let q: Vec<i8> = (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect();
            let plan = QueryPlan::topk(10)
                .seed(round as u64 * 31 + 7)
                .prune(prune)
                .build()
                .unwrap();
            let s = chip_s.execute(&q, &plan.with_exec(Exec::Serial));
            let p = chip_p.execute(&q, &plan.with_exec(Exec::Pool(Arc::clone(&pool))));
            let ctx = format!("round {round} {prune:?}");
            assert_eq!(s.topk, p.topk, "{ctx}: ranking");
            assert_stats_identical(&s.stats, &p.stats, &ctx);
        }

        let adds: Vec<DocPayload> = (0..4).map(|i| payload(next_extra + i)).collect();
        next_extra += 4;
        let (ids_s, _) = chip_s.add_docs(&adds, &mut w_s).expect("add");
        let (ids_p, _) = chip_p.add_docs(&adds, &mut w_p).expect("add");
        assert_eq!(ids_s, ids_p, "round {round}: assigned ids diverged");

        let upd: Vec<(u64, DocPayload)> = (0..2)
            .map(|i| ((round * 29 + i * 11) as u64 % n as u64, payload(next_extra + i)))
            .collect();
        next_extra += 2;
        chip_s.update_docs(&upd, &mut w_s).expect("update");
        chip_p.update_docs(&upd, &mut w_p).expect("update");

        let dels = [(round * 37 + 5) as u64 % n as u64];
        chip_s.delete_docs(&dels);
        chip_p.delete_docs(&dels);
        assert_eq!(chip_s.n_docs(), chip_p.n_docs(), "round {round}: corpus size");
    }

    // Post-churn: pooled batch matrix vs serial batch, pruned.
    let mut qrng = Pcg::new(95);
    let queries: Vec<Vec<i8>> = (0..5)
        .map(|_| (0..dim).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();
    let plan = QueryPlan::topk(10).seed(96).build().unwrap();
    let want = chip_s.execute_batch(&queries, &plan.with_exec(Exec::Serial));
    let got = chip_p.execute_batch(&queries, &plan.with_exec(Exec::Pool(Arc::clone(&pool))));
    for (qi, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.topk, w.topk, "post-churn pruned batch query {qi}");
        assert_stats_identical(&g.stats, &w.stats, &format!("post-churn pruned batch query {qi}"));
    }
    assert_eq!(pool.panicked(), 0);
}

#[test]
fn pooled_sim_engine_end_to_end_identical() {
    let mut rng = Pcg::new(61);
    let fp = random_unit_rows(384, 128, &mut rng);
    let db = quantize(&fp, 384, 128, QuantScheme::Int8);
    let cfg = || ChipConfig {
        cores: 4,
        map_points: 40,
        ..ChipConfig::paper_default(128, Metric::Cosine)
    };
    let serial = SimEngine::new(cfg(), &db);
    let pool = Arc::new(ThreadPool::new(4));
    let pooled = SimEngine::with_pool(cfg(), &db, Some(Arc::clone(&pool)));

    let mut qrng = Pcg::new(7);
    let queries: Vec<Vec<i8>> = (0..6)
        .map(|_| (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect())
        .collect();

    // Single-query path: the same Auto plan resolves serial on one
    // engine and pooled on the other — identical results.
    for (qi, q) in queries.iter().enumerate() {
        let plan = QueryPlan::topk(5).seed(qi as u64).build().unwrap();
        let a = serial.retrieve(q, &plan);
        let b = pooled.retrieve(q, &plan);
        assert_eq!(a.topk, b.topk, "query {qi}");
        assert_stats_identical(&a.stats, &b.stats, &format!("engine query {qi}"));
    }

    // Batch path vs the serial engine's per-query nonce loop.
    let plan = QueryPlan::topk(5).seed(99).build().unwrap();
    let want = serial.retrieve_batch(&queries, &plan);
    let got = pooled.retrieve_batch(&queries, &plan);
    for (qi, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.topk, w.topk, "batch query {qi}");
        assert_stats_identical(&g.stats, &w.stats, &format!("engine batch query {qi}"));
    }
    assert_eq!(pool.panicked(), 0);
}
