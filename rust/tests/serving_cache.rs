//! Serving cache hierarchy integration tests — the pin referenced by the
//! `retrieval::cache` module docs. The hot-query result cache and the
//! centroid-routing cache are driven end-to-end through `SimEngine` and
//! the coordinator: hits must be bit-identical to recompute (Seeded plans
//! only), every mutation must invalidate the result cache (add/update/
//! delete bursts), the routing cache must survive mutations without
//! perturbing results, and content-pinned dispatch must make serving
//! results independent of arrival order.

use std::sync::Arc;

use dirc_rag::coordinator::{
    Coordinator, CoordinatorConfig, Engine, Mutation, Query, SimEngine,
};
use dirc_rag::dirc::chip::ChipConfig;
use dirc_rag::retrieval::cache::CacheConfig;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::{ClusterPolicy, Prune};
use dirc_rag::util::rng::Pcg;

fn db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    quantize(&fp, n, dim, QuantScheme::Int8)
}

fn cfg(dim: usize, cores: usize) -> ChipConfig {
    ChipConfig { cores, map_points: 40, ..ChipConfig::paper_default(dim, Metric::Cosine) }
}

fn clustered_cfg(dim: usize, cores: usize, clusters: usize, nprobe: usize) -> ChipConfig {
    ChipConfig {
        cluster: ClusterPolicy { n_clusters: clusters, nprobe, kmeans_iters: 4 },
        ..cfg(dim, cores)
    }
}

/// Dequantised embedding of a stored row — a query/mutation payload in
/// the same space as the corpus.
fn emb_of(db: &Quantized, i: usize) -> Vec<f32> {
    db.row(i).iter().map(|&v| v as f32 * db.scale).collect()
}

/// A one-worker cached coordinator over a `SimEngine`, returning both
/// handles (the engine stays reachable for direct counter checks).
fn cached_coordinator(
    base: &Quantized,
    chip_cfg: ChipConfig,
    cache: CacheConfig,
) -> (Coordinator, Arc<SimEngine>) {
    let engine = Arc::new(SimEngine::with_caches(chip_cfg, base, None, cache));
    let ccfg = CoordinatorConfig { workers: 1, cache, ..CoordinatorConfig::default() };
    let coord = Coordinator::start_sim(Arc::clone(&engine) as Arc<dyn Engine>, ccfg);
    (coord, engine)
}

fn oracle(k: usize) -> QueryPlan {
    QueryPlan::topk(k).build().unwrap()
}

#[test]
fn hot_queries_hit_and_stay_bit_identical_through_the_coordinator() {
    let base = db(256, 128, 1);
    let cache = CacheConfig { result_entries: 64, routing_entries: 0 };
    let (coord, _engine) = cached_coordinator(&base, cfg(128, 4), cache);

    // One hot query served 6 times, interleaved with 4 distinct cold
    // queries. Sequential submit/recv: every repeat finds the first
    // answer already inserted.
    let hot = emb_of(&base, 3);
    let mut hot_resps = Vec::new();
    for i in 0..10 {
        let q = if i % 2 == 0 { hot.clone() } else { emb_of(&base, 10 + i) };
        let (_, rx) = coord.submit(Query::Embedding(q), oracle(5)).unwrap();
        let resp = rx.recv().expect("query answered");
        if i % 2 == 0 {
            hot_resps.push(resp);
        }
    }
    // Bit-identity across every serving of the hot query: same docs,
    // same scores, same modeled hardware accounting to the bit.
    let first = &hot_resps[0];
    assert_eq!(first.topk[0].doc_id, 3, "a corpus row is its own best match");
    for r in &hot_resps[1..] {
        assert_eq!(r.topk, first.topk);
        assert_eq!(r.stats.sense, first.stats.sense);
        assert_eq!(r.stats.cycles, first.stats.cycles);
        assert_eq!(r.stats.energy_j.to_bits(), first.stats.energy_j.to_bits());
    }

    let snap = coord.shutdown();
    assert_eq!(snap.served, 10);
    let cache = snap.cache.expect("cached engine must surface counters");
    // 1 hot miss + 4 repeats served from cache + 5 distinct misses.
    assert_eq!(cache.results.hits, 4);
    assert_eq!(cache.results.misses, 6);
    assert!(snap.render().contains("caches:"));
}

#[test]
fn mutation_bursts_invalidate_the_result_cache() {
    let base = db(200, 128, 2);
    let cache = CacheConfig { result_entries: 32, routing_entries: 0 };
    let (coord, _engine) = cached_coordinator(&base, cfg(128, 4), cache);
    let fresh = db(2, 128, 91);

    let ask = |q: &Vec<f32>| {
        let (_, rx) = coord.submit(Query::Embedding(q.clone()), oracle(5)).unwrap();
        rx.recv().expect("query answered")
    };

    // Warm the cache on both probe embeddings, then ingest fresh doc 0.
    let q0 = emb_of(&fresh, 0);
    let q1 = emb_of(&fresh, 1);
    let before = ask(&q0);
    assert!(
        before.topk.iter().all(|d| d.doc_id != 200),
        "doc 200 must not exist before the add"
    );
    ask(&q1);
    let (_, mrx) = coord.submit_mutation(Mutation::Add { docs: vec![q0.clone()] }).unwrap();
    assert_eq!(mrx.recv().expect("add applied").added_ids, vec![200]);

    // A stale cache would replay `before` (no doc 200); invalidation
    // forces a recompute on the post-add snapshot.
    assert_eq!(ask(&q0).topk[0].doc_id, 200, "added doc must be its own best match");

    // In-place update: doc 200 becomes fresh-1; the q1 entry (cached
    // before the add) must not survive two intervening mutations.
    let (_, mrx) = coord
        .submit_mutation(Mutation::Update { docs: vec![(200, q1.clone())] })
        .unwrap();
    assert_eq!(mrx.recv().expect("update applied").stats.docs_updated, 1);
    assert_eq!(ask(&q1).topk[0].doc_id, 200, "updated doc must match its new embedding");

    // Tombstone it: cached results naming doc 200 must not come back.
    let (_, mrx) = coord.submit_mutation(Mutation::Delete { ids: vec![200] }).unwrap();
    assert_eq!(mrx.recv().expect("delete applied").stats.docs_deleted, 1);
    assert!(ask(&q1).topk.iter().all(|d| d.doc_id != 200));

    let snap = coord.shutdown();
    let cache = snap.cache.expect("cache counters");
    assert_eq!(cache.results.invalidations, 3, "one invalidation per mutation batch");
    assert_eq!(snap.mutations, 3);
}

#[test]
fn routing_cache_survives_mutations_without_perturbing_results() {
    // Centroid rankings depend only on the build-time centroids, so the
    // routing cache is NOT invalidated by mutations — and a cached
    // engine must stay bit-identical to an uncached one through the
    // same mutation stream.
    let base = db(400, 128, 3);
    let chip_cfg = clustered_cfg(128, 8, 8, 2);
    let cached = SimEngine::with_caches(
        chip_cfg.clone(),
        &base,
        None,
        CacheConfig { result_entries: 0, routing_entries: 32 },
    );
    let plain = SimEngine::with_caches(chip_cfg, &base, None, CacheConfig::default());

    let queries: Vec<Vec<i8>> = (0..4).map(|i| base.row(i * 7).to_vec()).collect();
    let plans = [
        QueryPlan::topk(5).seed(11).build().unwrap(),
        QueryPlan::topk(5).prune(Prune::Probe(3)).seed(11).build().unwrap(),
        QueryPlan::topk(5).prune(Prune::adaptive(0.05, 6)).seed(11).build().unwrap(),
    ];
    let check_all = |label: &str| {
        for plan in &plans {
            for q in &queries {
                let a = cached.retrieve(q, plan);
                let b = plain.retrieve(q, plan);
                assert_eq!(a.topk, b.topk, "{label}: topk diverged");
                assert_eq!(a.stats.cycles, b.stats.cycles, "{label}: cycles diverged");
                assert_eq!(
                    a.stats.clusters_probed, b.stats.clusters_probed,
                    "{label}: probe accounting diverged"
                );
            }
        }
    };
    check_all("pre-mutation");

    // Identical mutation streams on both engines (same rng seeds).
    let fresh = db(6, 128, 44);
    let docs: Vec<Vec<f32>> = (0..6).map(|i| emb_of(&fresh, i)).collect();
    let mut r1 = Pcg::new(5);
    let mut r2 = Pcg::new(5);
    cached.mutate(&Mutation::Add { docs: docs.clone() }, &mut r1).unwrap();
    plain.mutate(&Mutation::Add { docs }, &mut r2).unwrap();
    cached.mutate(&Mutation::Delete { ids: vec![13, 99] }, &mut r1).unwrap();
    plain.mutate(&Mutation::Delete { ids: vec![13, 99] }, &mut r2).unwrap();
    check_all("post-mutation");

    let stats = cached.cache_stats().expect("routing cache on");
    assert_eq!(stats.routing.invalidations, 0, "mutations must not clear routing");
    assert!(stats.routing.hits > 0, "repeat rankings must be served from cache");
    assert_eq!(stats.results.hits + stats.results.misses, 0, "result cache is off");
}

#[test]
fn content_pinned_dispatch_is_independent_of_arrival_order() {
    // With result caching on, workers stamp plans with content-pinned
    // seeds — so what a query returns cannot depend on which dispatch
    // (or coordinator lifetime) served it. Two coordinators over
    // identically built engines, fed the same queries in opposite
    // orders, must answer each query bit-identically.
    let base = db(220, 128, 6);
    let cache = CacheConfig { result_entries: 16, routing_entries: 0 };
    let (coord_a, _ea) = cached_coordinator(&base, cfg(128, 4), cache);
    let (coord_b, _eb) = cached_coordinator(&base, cfg(128, 4), cache);

    let ids: Vec<usize> = vec![5, 17, 60, 101, 219];
    let ask = |coord: &Coordinator, i: usize| {
        let (_, rx) = coord.submit(Query::Embedding(emb_of(&base, i)), oracle(5)).unwrap();
        rx.recv().expect("query answered")
    };
    let a: Vec<_> = ids.iter().map(|&i| ask(&coord_a, i)).collect();
    let b: Vec<_> = ids.iter().rev().map(|&i| ask(&coord_b, i)).collect();
    for (ra, rb) in a.iter().zip(b.iter().rev()) {
        assert_eq!(ra.topk, rb.topk);
        assert_eq!(ra.stats.sense, rb.stats.sense);
        assert_eq!(ra.stats.energy_j.to_bits(), rb.stats.energy_j.to_bits());
    }
    coord_a.shutdown();
    coord_b.shutdown();
}
