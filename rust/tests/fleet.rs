//! Fleet-equivalence golden tests: the determinism contract of
//! [`DircFleet`] (fleet == one big chip, bit for bit) pinned against
//! the bare [`DircChip`] — ids, score bits, the full hardware census,
//! mutations over shared rng streams, and the scatter-gather merge's
//! (score desc, global id asc) total order on tie-heavy corpora.

use dirc_rag::dirc::chip::{ChipConfig, DircChip, DocPayload, MutationStats, QueryStats};
use dirc_rag::fleet::DircFleet;
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme, Quantized};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::topk::{merge_local, ScoredDoc};
use dirc_rag::retrieval::Prune;
use dirc_rag::util::rng::Pcg;

fn db_of(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let docs: Vec<f32> = (0..n * dim).map(|_| rng.int_in(-128, 127) as f32 / 128.0).collect();
    quantize(&docs, n, dim, QuantScheme::Int8)
}

fn clustered_cfg(cores: usize, n_clusters: usize) -> ChipConfig {
    ChipConfig {
        cores,
        map_points: 25,
        cluster: ClusterPolicy { n_clusters, nprobe: 2, kmeans_iters: 6 },
        ..ChipConfig::paper_default(128, Metric::Mips)
    }
}

fn query(dim: usize, seed: u64) -> Vec<i8> {
    let mut rng = Pcg::new(seed);
    (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect()
}

/// Top-k equality down to the score *bits* (ScoredDoc's `==` already
/// compares exact f64 values; the bit view makes -0.0/NaN drift loud).
fn assert_topk_bits(got: &[ScoredDoc], want: &[ScoredDoc], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: result count");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.doc_id, b.doc_id, "{ctx}: rank {i} id");
        assert_eq!(
            a.score.to_bits(),
            b.score.to_bits(),
            "{ctx}: rank {i} score bits (doc {})",
            a.doc_id
        );
    }
}

/// Field-by-field QueryStats equality, floats compared by bits.
fn assert_stats_bits(got: &QueryStats, want: &QueryStats, ctx: &str) {
    assert_eq!(got.sense, want.sense, "{ctx}: sense census");
    assert_eq!(got.cycles, want.cycles, "{ctx}: cycles");
    assert_eq!(got.work_cycles, want.work_cycles, "{ctx}: work_cycles");
    assert_eq!(got.macros_sensed, want.macros_sensed, "{ctx}: macros_sensed");
    assert_eq!(got.macros_skipped, want.macros_skipped, "{ctx}: macros_skipped");
    assert_eq!(got.docs_scored, want.docs_scored, "{ctx}: docs_scored");
    assert_eq!(got.clusters_probed, want.clusters_probed, "{ctx}: clusters_probed");
    assert_eq!(
        got.latency_s.to_bits(),
        want.latency_s.to_bits(),
        "{ctx}: latency bits"
    );
    assert_eq!(got.energy_j.to_bits(), want.energy_j.to_bits(), "{ctx}: energy bits");
}

fn mutation_stats_eq(a: &MutationStats, b: &MutationStats, ctx: &str) {
    assert_eq!(a.docs_added, b.docs_added, "{ctx}: docs_added");
    assert_eq!(a.docs_updated, b.docs_updated, "{ctx}: docs_updated");
    assert_eq!(a.docs_deleted, b.docs_deleted, "{ctx}: docs_deleted");
    assert_eq!(a.missing_ids, b.missing_ids, "{ctx}: missing_ids");
    assert_eq!(a.write_pulses, b.write_pulses, "{ctx}: write_pulses");
    assert_eq!(a.write_cycles, b.write_cycles, "{ctx}: write_cycles");
    assert_eq!(a.per_core.len(), b.per_core.len(), "{ctx}: per_core len");
    for (c, (x, y)) in a.per_core.iter().zip(&b.per_core).enumerate() {
        assert_eq!(x.cells_written, y.cells_written, "{ctx}: core {c} cells");
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{ctx}: core {c} energy");
        assert_eq!(x.time_s.to_bits(), y.time_s.to_bits(), "{ctx}: core {c} time");
    }
}

/// An N=1 fleet is the bare chip, bit for bit: ids, score bits, and the
/// full hardware census (including energy bits) across every prune
/// policy, seeded.
#[test]
fn n1_fleet_bit_identical_to_bare_chip_across_plans() {
    let db = db_of(480, 128, 0xF1EE7);
    let cfg = clustered_cfg(8, 16);
    let chip = DircChip::build(cfg.clone(), &db);
    let fleet = DircFleet::build(cfg, &db, 1);
    assert_eq!(fleet.n_chips(), 1);
    assert_eq!(fleet.n_docs(), chip.n_docs());

    let prunes = [
        Prune::None,
        Prune::Default,
        Prune::Probe(3),
        Prune::Probe(16), // >= n_clusters: the exhaustive degradation
        Prune::adaptive(0.05, 6),
        Prune::adaptive(0.0, 4), // disarmed: Probe(4) degradation
    ];
    for (pi, &prune) in prunes.iter().enumerate() {
        for seed in 0..4u64 {
            let q = query(128, 1000 + seed);
            let plan = QueryPlan::topk(7)
                .prune(prune)
                .seed(40 + pi as u64 * 10 + seed)
                .build()
                .unwrap();
            let want = chip.execute(&q, &plan);
            let got = fleet.execute(&q, &plan);
            let ctx = format!("plan {pi} seed {seed}");
            assert_topk_bits(&got.topk, &want.topk, &ctx);
            assert_stats_bits(&got.stats, &want.stats, &ctx);
        }
    }
}

/// N=1 bit-identity holds *through* mutations: the fleet's add/update/
/// delete draw from the shared rng stream exactly as the bare chip does
/// (same assigned ids, same write accounting), and post-churn queries
/// still return identical bits.
#[test]
fn n1_fleet_bit_identical_through_mutations() {
    let db = db_of(240, 128, 0xADD5);
    let cfg = clustered_cfg(4, 8);
    let mut chip = DircChip::build(cfg.clone(), &db);
    let mut fleet = DircFleet::build(cfg, &db, 1);
    let mut rc = Pcg::new(77);
    let mut rf = Pcg::new(77);
    let mut payload_rng = Pcg::new(31);
    let mut payloads = |n: usize| -> Vec<DocPayload> {
        (0..n)
            .map(|_| {
                DocPayload::from_values(
                    (0..128).map(|_| payload_rng.int_in(-128, 127) as i8).collect(),
                )
            })
            .collect()
    };

    // Adds: same ids, same accounting.
    let adds = payloads(9);
    let (ids_c, st_c) = chip.add_docs(&adds, &mut rc).unwrap();
    let (ids_f, st_f) = fleet.add_docs(&adds, &mut rf).unwrap();
    assert_eq!(ids_c, ids_f, "assigned global ids");
    mutation_stats_eq(&st_c, &st_f, "add");
    for &id in &ids_f {
        assert_eq!(fleet.shard_of(id), Some(0));
    }

    // Updates, including a never-seen id: both sides must count it in
    // missing_ids without touching the rng stream.
    let fresh = payloads(4);
    let mut updates: Vec<(u64, DocPayload)> = vec![
        (3, fresh[0].clone()),
        (9_999_999, fresh[1].clone()),
        (ids_c[0], fresh[2].clone()),
        (120, fresh[3].clone()),
    ];
    let st_c = chip.update_docs(&updates, &mut rc).unwrap();
    let st_f = fleet.update_docs(&updates, &mut rf).unwrap();
    assert_eq!(st_f.missing_ids, 1, "one unknown update target");
    mutation_stats_eq(&st_c, &st_f, "update");

    // Deletes (one missing), then a post-churn query: still bit-identical.
    let dels = [ids_c[1], 5, 8_888_888];
    let st_c = chip.delete_docs(&dels);
    let st_f = fleet.delete_docs(&dels);
    mutation_stats_eq(&st_c, &st_f, "delete");
    assert_eq!(fleet.shard_of(ids_c[1]), None, "deleted id leaves the directory");
    assert_eq!(fleet.n_docs(), chip.n_docs());

    // A second round keeps the streams locked (updates after adds reuse
    // fleet-assigned ids).
    updates = vec![(ids_c[2], payloads(1)[0].clone())];
    let st_c2 = chip.update_docs(&updates, &mut rc).unwrap();
    let st_f2 = fleet.update_docs(&updates, &mut rf).unwrap();
    mutation_stats_eq(&st_c2, &st_f2, "second update");

    for seed in 0..4u64 {
        let q = query(128, 7000 + seed);
        for prune in [Prune::None, Prune::Default] {
            let plan = QueryPlan::topk(6).prune(prune).seed(300 + seed).build().unwrap();
            let want = chip.execute(&q, &plan);
            let got = fleet.execute(&q, &plan);
            let ctx = format!("post-churn seed {seed} {prune:?}");
            assert_topk_bits(&got.topk, &want.topk, &ctx);
            assert_stats_bits(&got.stats, &want.stats, &ctx);
        }
    }
}

/// A fleet of 4 returns exactly the (score desc, global id asc) merge of
/// the per-shard top-ks — checked both against an independent
/// reconstruction of the scatter (route -> per-shard execute ->
/// merge_local) and against the bare union chip's bits.
#[test]
fn fleet_of_4_is_exactly_the_merged_per_shard_topk() {
    let db = db_of(480, 128, 0x5CA7);
    let cfg = clustered_cfg(8, 16);
    let chip = DircChip::build(cfg.clone(), &db);
    let fleet = DircFleet::build(cfg, &db, 4);
    assert_eq!(fleet.n_chips(), 4);

    for seed in 0..6u64 {
        let q = query(128, 2000 + seed);
        for (k, prune) in [(5, Prune::Probe(3)), (9, Prune::Default), (7, Prune::None)] {
            let plan = QueryPlan::topk(k).prune(prune).seed(500 + seed).build().unwrap();
            let (got, per_shard) = fleet.execute_scatter(&q, &plan);
            let ctx = format!("seed {seed} {prune:?}");

            // Same bits as the bare union chip.
            let want = chip.execute(&q, &plan);
            assert_topk_bits(&got.topk, &want.topk, &ctx);

            // Exactly the merge of the per-shard top-ks under the
            // fleet-resolved sub-plan.
            let route = fleet.route(&q, k, plan.prune());
            let sub = plan
                .with_nonce(plan.first_nonce())
                .with_prune(route.sub_prune)
                .unwrap();
            let mut locals = Vec::new();
            for (s, sh) in fleet.shards().iter().enumerate() {
                assert_eq!(
                    route.targets[s],
                    per_shard[s].is_some(),
                    "{ctx}: scatter hit exactly the routed shards"
                );
                if route.targets[s] {
                    let out = sh.execute_batch(&[q.clone()], &sub).pop().unwrap();
                    locals.push(out.topk);
                }
            }
            let merged = merge_local(&locals, k);
            assert_topk_bits(&got.topk, &merged, &format!("{ctx}: vs manual merge"));

            // Census closure: every core fleet-wide either sensed or was
            // skipped, and per-shard sensed counts sum to the merged view.
            assert_eq!(
                got.stats.macros_sensed + got.stats.macros_skipped,
                8,
                "{ctx}: macro census covers all fleet cores"
            );
            let sensed_sum: u32 =
                per_shard.iter().flatten().map(|st| st.macros_sensed).sum();
            assert_eq!(got.stats.macros_sensed, sensed_sum, "{ctx}: sensed sum");
        }
    }
}

/// Tie-heavy corpus (each distinct vector appears 40x): merge order must
/// fall back to global id ascending on equal scores, and the fleet must
/// still match the bare chip bit for bit while doing so.
#[test]
fn tie_heavy_corpus_merges_by_global_id() {
    let dim = 128;
    let distinct = 8;
    let reps = 40;
    let n = distinct * reps;
    let mut rng = Pcg::new(0x71E5);
    let protos: Vec<Vec<f32>> = (0..distinct)
        .map(|_| (0..dim).map(|_| rng.int_in(-128, 127) as f32 / 128.0).collect())
        .collect();
    // Interleave the prototypes so duplicates of one vector land on
    // *different* cores/shards — the merge has real cross-shard ties.
    let mut docs = Vec::with_capacity(n * dim);
    for _ in 0..reps {
        for p in &protos {
            docs.extend_from_slice(p);
        }
    }
    let db = quantize(&docs, n, dim, QuantScheme::Int8);
    // Exhaustive chip (no clustering): every duplicate is scored.
    let cfg = ChipConfig {
        cores: 8,
        map_points: 25,
        ..ChipConfig::paper_default(dim, Metric::Mips)
    };
    let chip = DircChip::build(cfg.clone(), &db);
    let fleet = DircFleet::build(cfg, &db, 4);

    for seed in 0..4u64 {
        let q = query(dim, 9000 + seed);
        let k = 3 * reps; // deep enough to span many full tie groups
        let plan = QueryPlan::topk(k).seed(seed).build().unwrap();
        let want = chip.execute(&q, &plan);
        let got = fleet.execute(&q, &plan);
        let ctx = format!("tie corpus seed {seed}");
        assert_topk_bits(&got.topk, &want.topk, &ctx);
        // The total order really is (score desc, id asc) — with 40
        // copies per vector the result is dominated by exact ties.
        let mut ties = 0;
        for w in got.topk.windows(2) {
            assert!(w[0].score >= w[1].score, "{ctx}: scores descend");
            if w[0].score == w[1].score {
                assert!(w[0].doc_id < w[1].doc_id, "{ctx}: ties break by id asc");
                ties += 1;
            }
        }
        assert!(ties >= reps / 2, "{ctx}: tie-heavy fixture produced {ties} ties");
    }
}

/// Shard-count invariance of batches: `DircFleet::execute_batch` draws
/// nonces in query order exactly like the chip, so whole batches are
/// bit-identical at 1, 2, and 4 shards and against the bare chip.
#[test]
fn batch_execution_invariant_across_shard_counts() {
    let db = db_of(480, 128, 0xBA7C);
    let cfg = clustered_cfg(8, 16);
    let chip = DircChip::build(cfg.clone(), &db);
    let queries: Vec<Vec<i8>> = (0..6).map(|i| query(128, 4000 + i)).collect();
    let plan = QueryPlan::topk(8).prune(Prune::Default).seed(11).build().unwrap();
    let want = chip.execute_batch(&queries, &plan);
    for chips in [1usize, 2, 4] {
        let fleet = DircFleet::build(cfg.clone(), &db, chips);
        let got = fleet.execute_batch(&queries, &plan);
        assert_eq!(got.len(), want.len());
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_topk_bits(&g.topk, &w.topk, &format!("x{chips} query {qi}"));
        }
    }
}
