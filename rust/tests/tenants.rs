//! Multi-tenant QoS integration tests: weighted deficit-round-robin
//! admission through the full coordinator (submit_for -> ingest ->
//! per-tenant DRR work queues -> worker), per-tenant plan templates, and
//! the per-tenant metrics identity (tenant counters sum to the globals).

use std::sync::Arc;
use std::time::Duration;

use dirc_rag::coordinator::batcher::BatchPolicy;
use dirc_rag::coordinator::{
    Coordinator, CoordinatorConfig, Engine, Query, SimEngine, TenantSpec,
};
use dirc_rag::dirc::chip::ChipConfig;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::util::rng::Pcg;

fn db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    quantize(&fp, n, dim, QuantScheme::Int8)
}

fn emb(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..dim).map(|_| rng.normal() as f32).collect()
}

/// Saturating two-tenant load at weights 3:1 on one worker: among the
/// earliest completions the served counts must split (close to) 3:1 —
/// the DRR guarantee — and the per-tenant snapshot counters must sum to
/// the global ones at shutdown.
#[test]
fn weighted_tenants_complete_near_their_drr_shares() {
    let dim = 128;
    let base = db(1536, dim, 1);
    let engine = Arc::new(SimEngine::new(
        ChipConfig { cores: 4, map_points: 30, ..ChipConfig::paper_default(dim, Metric::Mips) },
        &base,
    ));
    let ccfg = CoordinatorConfig {
        workers: 1,
        // Hold ingest flushes to 32-query batches so the work queues fill
        // much faster than one worker drains them — the fairness ratio is
        // only defined under saturation.
        batch: BatchPolicy { sizes: vec![32], max_wait: Duration::from_millis(20) },
        tenants: vec![
            TenantSpec { name: "gold".into(), weight: 3, plan: None },
            TenantSpec { name: "best_effort".into(), weight: 1, plan: None },
        ],
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_sim(engine as Arc<dyn Engine>, ccfg);
    assert_eq!(coord.tenant_names(), vec!["gold".to_string(), "best_effort".to_string()]);

    // 360 queries per tenant, submitted interleaved (so both DRR queues
    // fill together and neither ever idles while measured).
    let per_tenant = 360usize;
    let mut pending = Vec::with_capacity(per_tenant * 2);
    for i in 0..per_tenant {
        for name in ["gold", "best_effort"] {
            let (_, rx) = coord
                .submit_for(name, Query::Embedding(emb(dim, 100 + i as u64)))
                .expect("submit");
            pending.push((name, Some(rx)));
        }
    }

    // Sweep the response channels until ~240 queries have completed.
    // Only stop at sweep boundaries: a full sweep's collected set is
    // exactly the served-so-far set (regardless of sweep order), so its
    // tenant split reflects the DRR serving order without bias.
    let measure = 240usize;
    let mut gold = 0usize;
    let mut best_effort = 0usize;
    while gold + best_effort < measure {
        let mut progressed = false;
        for (name, rx) in pending.iter_mut() {
            let Some(ch) = rx else { continue };
            if let Ok(resp) = ch.try_recv() {
                assert_eq!(resp.topk.len(), 10, "default plan template");
                match *name {
                    "gold" => gold += 1,
                    _ => best_effort += 1,
                }
                *rx = None;
                progressed = true;
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let ratio = gold as f64 / best_effort.max(1) as f64;
    assert!(
        (2.7..=3.3).contains(&ratio),
        "completed {gold}:{best_effort} (ratio {ratio:.2}) — expected within 10% of 3:1"
    );

    // Drain the rest, then check the metrics identity on the final
    // snapshot: per-tenant served/errors sum to the global counters.
    for (_, rx) in pending.iter_mut() {
        if let Some(ch) = rx.take() {
            ch.recv().expect("response");
        }
    }
    let snap = coord.shutdown();
    assert_eq!(snap.served, (per_tenant * 2) as u64);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.tenants.len(), 2);
    let served_sum: u64 = snap.tenants.iter().map(|t| t.served).sum();
    let errors_sum: u64 = snap.tenants.iter().map(|t| t.errors).sum();
    assert_eq!(served_sum, snap.served, "tenant served counters sum to global");
    assert_eq!(errors_sum, snap.errors, "tenant error counters sum to global");
    for t in &snap.tenants {
        assert_eq!(t.served, per_tenant as u64, "both tenants fully drained");
        assert!(t.host_latency_mean_s > 0.0, "tenant {} latency tracked", t.name);
    }
}

/// Per-tenant QueryPlan templates: a tenant with its own plan serves
/// under it, a tenant without one inherits the coordinator's default
/// template, and unknown tenant names are rejected at submit.
#[test]
fn tenant_plan_templates_and_unknown_tenants() {
    let dim = 128;
    let base = db(256, dim, 2);
    let engine = Arc::new(SimEngine::new(
        ChipConfig { cores: 2, map_points: 25, ..ChipConfig::paper_default(dim, Metric::Mips) },
        &base,
    ));
    let ccfg = CoordinatorConfig {
        workers: 1,
        tenants: vec![
            TenantSpec {
                name: "gold".into(),
                weight: 3,
                plan: Some(QueryPlan::topk(3).seed(9).build().unwrap()),
            },
            TenantSpec { name: "free".into(), weight: 1, plan: None },
        ],
        default_plan: QueryPlan::topk(4).build().unwrap(),
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_sim(engine as Arc<dyn Engine>, ccfg);

    let (_, rx_gold) =
        coord.submit_for("gold", Query::Embedding(emb(dim, 5))).expect("gold submit");
    let (_, rx_free) =
        coord.submit_for("free", Query::Embedding(emb(dim, 6))).expect("free submit");
    assert_eq!(rx_gold.recv().unwrap().topk.len(), 3, "tenant template plan");
    assert_eq!(rx_free.recv().unwrap().topk.len(), 4, "default template plan");
    assert!(
        coord.submit_for("platinum", Query::Embedding(emb(dim, 7))).is_err(),
        "unknown tenants are rejected"
    );

    // Plain submit() still works on a multi-tenant coordinator: it books
    // under the first tenant with an explicit plan.
    let (_, rx) = coord
        .submit(Query::Embedding(emb(dim, 8)), QueryPlan::topk(2).build().unwrap())
        .expect("submit");
    assert_eq!(rx.recv().unwrap().topk.len(), 2);

    let snap = coord.shutdown();
    assert_eq!(snap.served, 3);
    let by_name: std::collections::HashMap<_, _> =
        snap.tenants.iter().map(|t| (t.name.as_str(), t.served)).collect();
    assert_eq!(by_name["gold"], 2, "submit() books under tenant 0");
    assert_eq!(by_name["free"], 1);
}
