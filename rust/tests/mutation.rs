//! Online corpus-mutation tests: live document writes on a serving chip
//! (`add_docs` / `delete_docs` / `update_docs`), the engine snapshot
//! swap, and the coordinator's serve-mode mutation channel with its
//! query-idle admission policy and shutdown drain.
//!
//! Everything here is deterministic or self-consistent — no assertion
//! depends on a value that could drift with the error-map Monte-Carlo.

use std::sync::Arc;

use dirc_rag::coordinator::{Coordinator, CoordinatorConfig, Mutation, Query, SimEngine};
use dirc_rag::dirc::chip::{ChipConfig, DircChip, DocPayload};
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::Prune;
use dirc_rag::util::rng::Pcg;

fn db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    quantize(&fp, n, dim, QuantScheme::Int8)
}

/// The clean-oracle plan (exhaustive, ideal readout) at `k`.
fn oracle(k: usize) -> QueryPlan {
    QueryPlan::topk(k).prune(Prune::None).build().unwrap()
}

fn cfg(dim: usize, cores: usize) -> ChipConfig {
    ChipConfig {
        cores,
        map_points: 40,
        ..ChipConfig::paper_default(dim, Metric::Cosine)
    }
}

/// A payload that reuses a database row verbatim (values + stored norm).
fn payload_of(db: &Quantized, i: usize) -> DocPayload {
    DocPayload { values: db.row(i).to_vec(), norm: db.norms[i] }
}

#[test]
fn added_doc_is_retrievable_and_costed() {
    let base = db(400, 128, 1);
    let extra = db(8, 128, 99); // fresh embeddings to ingest
    let mut chip = DircChip::build(cfg(128, 4), &base);
    assert_eq!(chip.n_docs(), 400);

    let mut rng = Pcg::new(5);
    let payloads: Vec<DocPayload> = (0..3).map(|i| payload_of(&extra, i)).collect();
    let (ids, stats) = chip.add_docs(&payloads, &mut rng).expect("capacity available");
    assert_eq!(ids, vec![400, 401, 402]);
    assert_eq!(stats.docs_added, 3);
    assert_eq!(chip.n_docs(), 403);

    // Measured write cost: pulses flowed, per-core costs sum to total.
    assert!(stats.write_pulses > 0);
    assert!(stats.write_cycles > 0);
    let total = stats.total();
    assert!(total.energy_j > 0.0 && total.time_s > 0.0 && total.cells_written > 0);
    // A dim-128 INT8 doc spans 128*8 bits = 512 MLC cells; three docs.
    assert_eq!(total.cells_written, 3 * 128 * 8 / 2);

    // The clean oracle finds each new doc as its own nearest neighbour
    // (cosine 1.0 against itself; random unit rows never tie that).
    for (i, &id) in ids.iter().enumerate() {
        let top = chip.clean_execute(extra.row(i), &oracle(3));
        assert_eq!(top[0].doc_id, id, "added doc {id} not top-1 for its own query");
    }
    // Wear is on the ledger and the map rows it touched are flagged.
    assert!(chip.total_wear() >= stats.write_pulses);
    assert!(chip.stale_rows() != 0);
}

#[test]
fn deleted_doc_never_returned_and_slot_reused() {
    let base = db(10, 128, 2);
    let mut chip = DircChip::build(cfg(128, 1), &base);

    // Doc 3 is its own nearest neighbour before deletion.
    let q3 = base.row(3).to_vec();
    assert_eq!(chip.clean_execute(&q3, &oracle(1))[0].doc_id, 3);

    let del = chip.delete_docs(&[3]);
    assert_eq!(del.docs_deleted, 1);
    assert_eq!(del.missing_ids, 0);
    assert_eq!(del.total().cells_written, 0, "tombstoning writes no cells");
    assert_eq!(chip.n_docs(), 9);
    // Slots are positional: the macro still walks 10 slots.
    assert_eq!(chip.cores()[0].n_docs(), 10);
    assert_eq!(chip.cores()[0].n_live(), 9);

    // Never returned again — by the clean oracle or the noisy path.
    let top = chip.clean_execute(&q3, &oracle(10));
    assert!(top.iter().all(|d| d.doc_id != 3));
    let out = chip.execute(&q3, &QueryPlan::topk(9).seed(7).build().unwrap());
    let (noisy, stats) = (out.topk, out.stats);
    assert!(noisy.iter().all(|d| d.doc_id != 3));
    // The hardware still scores the tombstoned slot (positional walk).
    assert_eq!(stats.docs_scored, 10);

    // The next add reuses the tombstoned slot in place.
    let extra = db(1, 128, 55);
    let mut rng = Pcg::new(8);
    let (ids, _) = chip.add_docs(&[payload_of(&extra, 0)], &mut rng).unwrap();
    assert_eq!(ids, vec![10]);
    assert_eq!(chip.cores()[0].n_docs(), 10, "slot reused, not appended");
    assert_eq!(chip.cores()[0].doc_ids()[3], 10, "lowest tombstone reused");
    assert_eq!(chip.n_docs(), 10);
    assert_eq!(chip.clean_execute(extra.row(0), &oracle(1))[0].doc_id, 10);
}

#[test]
fn update_reprograms_in_place() {
    let base = db(200, 128, 3);
    let target = db(1, 128, 77);
    let mut chip = DircChip::build(cfg(128, 4), &base);
    let q = target.row(0).to_vec();

    let mut rng = Pcg::new(9);
    let stats = chip
        .update_docs(&[(42, payload_of(&target, 0))], &mut rng)
        .expect("update");
    assert_eq!(stats.docs_updated, 1);
    assert!(stats.write_pulses > 0);
    assert_eq!(chip.n_docs(), 200, "update does not change the corpus size");
    assert_eq!(chip.clean_execute(&q, &oracle(1))[0].doc_id, 42);

    // Unknown ids are counted, not fatal.
    let stats = chip
        .update_docs(&[(9999, payload_of(&target, 0))], &mut rng)
        .expect("missing id is not an error");
    assert_eq!(stats.docs_updated, 0);
    assert_eq!(stats.missing_ids, 1);
}

#[test]
fn chip_full_rejects_adds() {
    // 1 core x dim 512 INT8 -> capacity 512 docs, filled completely.
    let full = db(512, 512, 4);
    let cfg = ChipConfig { map_points: 20, ..cfg(512, 1) };
    assert_eq!(cfg.capacity_docs(), 512);
    let mut chip = DircChip::build(cfg, &full);
    let extra = db(1, 512, 5);
    let mut rng = Pcg::new(6);
    assert!(chip.add_docs(&[payload_of(&extra, 0)], &mut rng).is_err());
    // Tombstoning one slot makes room again.
    chip.delete_docs(&[0]);
    let (ids, _) = chip.add_docs(&[payload_of(&extra, 0)], &mut rng).unwrap();
    assert_eq!(ids, vec![512]);
}

#[test]
fn wear_crosses_threshold_and_lazily_refreshes_map_and_layouts() {
    let base = db(120, 128, 11);
    let cfg = ChipConfig {
        // Any wear at all forces the next mutation to re-characterise.
        wear_refresh_pulses: 1,
        ..cfg(128, 2)
    };
    let mut chip = DircChip::build(cfg, &base);
    assert_eq!(chip.map_epoch(), 0);

    let mut rng = Pcg::new(12);
    let upd: Vec<_> = (0..4u64).map(|id| (id, payload_of(&base, id as usize))).collect();
    let s1 = chip.update_docs(&upd, &mut rng).unwrap();
    // First batch: nothing was stale when it was admitted.
    assert_eq!(s1.map_rows_refreshed, 0);
    assert!(chip.stale_rows() != 0 && chip.total_wear() > 0);

    // Second batch sees the stale rows + wear and refreshes lazily.
    let s2 = chip.update_docs(&upd, &mut rng).unwrap();
    assert!(s2.map_rows_refreshed > 0, "stale rows must re-characterise");
    assert!(s2.layouts_rederived >= 1, "touched macros re-derive their layout");
    assert_eq!(chip.map_epoch(), 1);
    // The migration estimate is part of the per-core accounting.
    assert!(s2.total().energy_j > s1.total().energy_j);

    // Explicit refresh drains whatever the second batch re-dirtied.
    let s3 = chip.refresh_stale();
    assert!(s3.map_rows_refreshed > 0);
    assert_eq!(chip.stale_rows(), 0);
    assert_eq!(chip.map_epoch(), 2);
    // Idempotent once clean.
    let s4 = chip.refresh_stale();
    assert_eq!(s4.map_rows_refreshed, 0);
    assert_eq!(chip.map_epoch(), 2);

    // The chip still answers well-formed queries after re-layout.
    let q = base.row(0).to_vec();
    let top = chip.execute(&q, &QueryPlan::topk(5).seed(13).build().unwrap()).topk;
    assert_eq!(top.len(), 5);
    assert_eq!(chip.clean_execute(&q, &oracle(1))[0].doc_id, 0);
}

#[test]
fn mutation_determinism_same_batch_same_state() {
    // Two equal chips + the same mutation stream -> bit-identical query
    // behaviour afterwards.
    let base = db(300, 128, 21);
    let extra = db(6, 128, 22);
    let mut a = DircChip::build(cfg(128, 4), &base);
    let mut b = DircChip::build(cfg(128, 4), &base);
    let payloads: Vec<_> = (0..6).map(|i| payload_of(&extra, i)).collect();
    let mut r1 = Pcg::new(31);
    let mut r2 = Pcg::new(31);
    let (ids_a, sa) = a.add_docs(&payloads, &mut r1).unwrap();
    let (ids_b, sb) = b.add_docs(&payloads, &mut r2).unwrap();
    assert_eq!(ids_a, ids_b);
    assert_eq!(sa.write_pulses, sb.write_pulses);
    a.delete_docs(&[5, 17]);
    b.delete_docs(&[5, 17]);

    let mut qgen = Pcg::new(40);
    let q: Vec<i8> = (0..128).map(|_| qgen.int_in(-128, 127) as i8).collect();
    let plan = QueryPlan::topk(10).seed(41).build().unwrap();
    let oa = a.execute(&q, &plan);
    let ob = b.execute(&q, &plan);
    assert_eq!(oa.topk, ob.topk);
    assert_eq!(oa.stats.sense, ob.stats.sense);
    assert_eq!(oa.stats.cycles, ob.stats.cycles);
}

// ---------------------------------------------------------------------
// Coordinator: serve-mode mutation channel (no PJRT runtime needed).
// ---------------------------------------------------------------------

fn sim_coordinator(n: usize, dim: usize, workers: usize) -> (Coordinator, Quantized) {
    let base = db(n, dim, 51);
    let engine = Arc::new(SimEngine::new(cfg(dim, 4), &base));
    // Bind the coordinator from the active layered config (default.toml
    // plus any `DIRC_CONFIG` overlay — the CI stressed-corner job runs
    // this suite with configs/stressed_corner.toml active), so serving
    // knobs exercise the real binding path. The chip config above stays
    // explicit: these assertions are operating-point-independent.
    let file_cfg = dirc_rag::coordinator::configfile::load_layered(None)
        .expect("layered config loads");
    let mut ccfg: CoordinatorConfig =
        dirc_rag::coordinator::configfile::coordinator_config(&file_cfg)
            .expect("coordinator config binds");
    ccfg.workers = workers;
    let coord = Coordinator::start_sim(engine, ccfg);
    (coord, base)
}

/// Dequantised embedding of a stored row — a query/mutation payload in
/// the same space as the corpus.
fn emb_of(db: &Quantized, i: usize) -> Vec<f32> {
    db.row(i).iter().map(|&v| v as f32 * db.scale).collect()
}

#[test]
fn coordinator_serves_queries_and_mutations_without_runtime() {
    let (coord, base) = sim_coordinator(256, 128, 2);

    // Interleave queries with mutations on the live channel.
    let mut rxs = Vec::new();
    for i in 0..16 {
        let (id, rx) = coord.submit(Query::Embedding(emb_of(&base, i)), oracle(5)).unwrap();
        rxs.push((id, i, rx));
    }
    // Fresh embeddings (not near any query target, so the assertion on
    // query top-1 below cannot race the admission timing).
    let fresh = db(2, 128, 77);
    let (_, add_rx) = coord
        .submit_mutation(Mutation::Add {
            docs: vec![emb_of(&fresh, 0), emb_of(&fresh, 1)],
        })
        .unwrap();
    let (_, del_rx) = coord
        .submit_mutation(Mutation::Delete { ids: vec![200, 201, 4096] })
        .unwrap();

    for (id, i, rx) in rxs {
        let resp = rx.recv().expect("query answered");
        assert_eq!(resp.id, id);
        assert_eq!(resp.topk.len(), 5);
        // A corpus row is its own best match under cosine.
        assert_eq!(resp.topk[0].doc_id, i as u64);
    }
    let add = add_rx.recv().expect("mutation answered");
    assert_eq!(add.added_ids, vec![256, 257]);
    assert_eq!(add.stats.docs_added, 2);
    assert!(add.apply_s >= 0.0 && add.total_s >= add.apply_s);
    let del = del_rx.recv().expect("mutation answered");
    assert_eq!(del.stats.docs_deleted, 2);
    assert_eq!(del.stats.missing_ids, 1);

    let snap = coord.shutdown();
    assert_eq!(snap.served, 16);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.mutations, 2);
    assert_eq!(snap.docs_written, 2);
    assert_eq!(snap.docs_deleted, 2);
    assert!(snap.write_energy_j > 0.0);
    assert!(snap.render().contains("2 mutations"));
}

#[test]
fn token_queries_error_cleanly_without_embedder() {
    let (coord, _) = sim_coordinator(64, 128, 1);
    let (_, rx) = coord.submit(Query::Tokens(vec![1, 2, 3]), oracle(5)).unwrap();
    // The request is dropped (no embedder): the response channel closes.
    assert!(rx.recv().is_err());
    let snap = coord.shutdown();
    assert_eq!(snap.errors, 1);
    assert_eq!(snap.served, 0);
}

#[test]
fn shutdown_under_load_drains_in_flight_mutations() {
    let (coord, base) = sim_coordinator(256, 128, 3);

    // Burst: plenty of queries still queued when shutdown starts, plus a
    // stack of mutations behind them on the mutation channel.
    let mut qrxs = Vec::new();
    for i in 0..48 {
        let (_, rx) = coord
            .submit(Query::Embedding(emb_of(&base, i % 256)), oracle(5))
            .unwrap();
        qrxs.push(rx);
    }
    let mut mrxs = Vec::new();
    for b in 0..5 {
        let (_, rx) = coord
            .submit_mutation(Mutation::Update {
                docs: vec![(b as u64, emb_of(&base, b))],
            })
            .unwrap();
        mrxs.push(rx);
    }

    // Immediate shutdown: must drain BOTH channels before returning.
    let snap = coord.shutdown();
    assert_eq!(snap.served, 48, "shutdown must answer queued queries");
    assert_eq!(snap.mutations, 5, "shutdown must drain queued mutations");
    assert_eq!(snap.docs_written, 5);
    for rx in qrxs {
        // Every response is already buffered in its channel.
        rx.try_recv().expect("query response delivered before shutdown returned");
    }
    for rx in mrxs {
        let resp = rx
            .try_recv()
            .expect("mutation response delivered before shutdown returned");
        assert_eq!(resp.stats.docs_updated, 1);
    }
}

#[test]
fn mutation_visible_to_subsequent_queries() {
    let (coord, _base) = sim_coordinator(128, 128, 2);
    // Ingest a brand-new doc, wait for it, then query for it.
    let fresh = db(1, 128, 91);
    let (_, mrx) = coord
        .submit_mutation(Mutation::Add { docs: vec![emb_of(&fresh, 0)] })
        .unwrap();
    let added = mrx.recv().expect("mutation applied");
    assert_eq!(added.added_ids, vec![128]);

    let (_, rx) = coord.submit(Query::Embedding(emb_of(&fresh, 0)), oracle(3)).unwrap();
    let resp = rx.recv().expect("query answered");
    assert_eq!(resp.topk[0].doc_id, 128, "new doc must be its own best match");
    coord.shutdown();
}
