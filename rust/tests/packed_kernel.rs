//! Packed bit-plane kernel equivalence net: the [`ScoreBackend`] knob
//! must be invisible in every observable — integer scores, merged
//! top-k, sensing statistics, the cycle/energy census — on clean and
//! noisy paths, serial and pooled, exhaustive and pruned, INT8 and
//! INT4, before and after online mutations, tombstones and ties
//! included. The flip-injection contract (a sensed flip IS a plane
//! XOR) is cross-checked three ways at the end.

use std::sync::Arc;

use dirc_rag::dirc::chip::{ChipConfig, DircChip, DocPayload};
use dirc_rag::dirc::variation::VariationModel;
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::{QueryPlan, ScoreBackend};
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::{dot_i8, Metric};
use dirc_rag::retrieval::{PackedPlanes, PackedQuery, Prune};
use dirc_rag::util::pool::ThreadPool;
use dirc_rag::util::rng::Pcg;

fn db(n: usize, dim: usize, seed: u64, scheme: QuantScheme) -> Quantized {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    quantize(&fp, n, dim, scheme)
}

fn cfg(dim: usize, cores: usize, bits: usize) -> ChipConfig {
    ChipConfig {
        cores,
        bits,
        map_points: 40,
        ..ChipConfig::paper_default(dim, Metric::Cosine)
    }
}

fn rand_query(dim: usize, scheme: QuantScheme, rng: &mut Pcg) -> Vec<i8> {
    (0..dim)
        .map(|_| rng.int_in(scheme.qmin() as i64, scheme.qmax() as i64) as i8)
        .collect()
}

/// Full-output equality of one plan run under both backends: merged
/// top-k, sensing statistics, and the cycle/energy census, bit for bit.
fn assert_backends_identical(chip: &DircChip, q: &[i8], plan: &QueryPlan) {
    let walk = chip.execute(q, &plan.with_backend(ScoreBackend::Walk));
    let pack = chip.execute(q, &plan.with_backend(ScoreBackend::Packed));
    assert_eq!(walk.topk, pack.topk, "top-k diverged");
    assert_eq!(walk.stats.sense, pack.stats.sense, "sense stats diverged");
    assert_eq!(walk.stats.cycles, pack.stats.cycles);
    assert_eq!(walk.stats.work_cycles, pack.stats.work_cycles);
    assert_eq!(walk.stats.macros_sensed, pack.stats.macros_sensed);
    assert_eq!(walk.stats.macros_skipped, pack.stats.macros_skipped);
    assert_eq!(walk.stats.docs_scored, pack.stats.docs_scored);
    assert_eq!(walk.stats.latency_s.to_bits(), pack.stats.latency_s.to_bits());
    assert_eq!(walk.stats.energy_j.to_bits(), pack.stats.energy_j.to_bits());
}

// ---------------------------------------------------------------------
// Kernel-level: packed == dot_i8 on random corpora (both schemes).
// ---------------------------------------------------------------------

#[test]
fn packed_matches_dot_i8_on_random_corpora() {
    let mut rng = Pcg::new(1);
    for scheme in [QuantScheme::Int8, QuantScheme::Int4] {
        // Dims straddling u64-word boundaries on top of the macro-legal
        // multiples of 128 (the kernel itself has no 128 constraint).
        for &dim in &[60usize, 64, 128, 200, 512] {
            let n = 40;
            let q = db(n, dim, 7 + dim as u64, scheme);
            let planes = q.pack_planes();
            for _ in 0..4 {
                let probe = rand_query(dim, scheme, &mut rng);
                let qp = PackedQuery::pack(&probe, scheme.bits());
                let mut out = Vec::new();
                planes.score_into(&qp, &mut out);
                for d in 0..n {
                    assert_eq!(
                        out[d],
                        dot_i8(q.row(d), &probe),
                        "{scheme:?} dim {dim} doc {d}"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Chip-level: the backend knob is invisible under every plan shape.
// ---------------------------------------------------------------------

#[test]
fn backends_identical_serial_and_pooled_both_metrics() {
    for metric in [Metric::Mips, Metric::Cosine] {
        let base = db(400, 128, 2, QuantScheme::Int8);
        let chip = DircChip::build(
            ChipConfig { metric, ..cfg(128, 4, 8) },
            &base,
        );
        let mut rng = Pcg::new(3);
        let pool = Arc::new(ThreadPool::new(3));
        for s in 0..4u64 {
            let q = rand_query(128, QuantScheme::Int8, &mut rng);
            let serial = QueryPlan::topk(10).seed(s).build().unwrap();
            let pooled =
                QueryPlan::topk(10).seed(s).pool(Arc::clone(&pool)).build().unwrap();
            assert_backends_identical(&chip, &q, &serial);
            assert_backends_identical(&chip, &q, &pooled);
            // Cross-shape: pooled packed == serial walk, transitively.
            let a = chip.execute(&q, &serial.with_backend(ScoreBackend::Walk));
            let b = chip.execute(&q, &pooled.with_backend(ScoreBackend::Packed));
            assert_eq!(a.topk, b.topk, "{metric:?} seed {s}");
        }
    }
}

#[test]
fn backends_identical_int4_chip() {
    let base = db(300, 128, 4, QuantScheme::Int4);
    let chip = DircChip::build(cfg(128, 2, 4), &base);
    let mut rng = Pcg::new(5);
    for s in 0..3u64 {
        let q = rand_query(128, QuantScheme::Int4, &mut rng);
        assert_backends_identical(&chip, &q, &QueryPlan::topk(8).seed(s).build().unwrap());
    }
}

#[test]
fn backends_identical_under_pruning() {
    let base = db(1024, 128, 6, QuantScheme::Int8);
    let chip = DircChip::build(
        ChipConfig {
            cluster: ClusterPolicy { n_clusters: 16, nprobe: 3, kmeans_iters: 5 },
            ..cfg(128, 4, 8)
        },
        &base,
    );
    let mut rng = Pcg::new(7);
    for prune in [Prune::None, Prune::Default, Prune::Probe(1), Prune::Probe(16)] {
        let q = rand_query(128, QuantScheme::Int8, &mut rng);
        let plan = QueryPlan::topk(10).prune(prune).seed(11).build().unwrap();
        assert_backends_identical(&chip, &q, &plan);
    }
}

#[test]
fn backends_identical_on_tie_heavy_corpus() {
    // Every row duplicated 8x: the merged top-k is wall-to-wall score
    // ties, so any ordering daylight between the kernels would surface
    // as a different id sequence (ties break by lower doc id).
    let dim = 128;
    let distinct = db(50, dim, 8, QuantScheme::Int8);
    let mut values = Vec::with_capacity(400 * dim);
    for i in 0..400 {
        values.extend_from_slice(distinct.row(i % 50));
    }
    let tied = Quantized {
        scheme: QuantScheme::Int8,
        n: 400,
        dim,
        values,
        scale: distinct.scale,
        norms: (0..400).map(|i| distinct.norms[i % 50]).collect(),
    };
    let chip = DircChip::build(cfg(dim, 4, 8), &tied);
    let mut rng = Pcg::new(9);
    for s in 0..3u64 {
        let q = rand_query(dim, QuantScheme::Int8, &mut rng);
        let plan = QueryPlan::topk(20).seed(s).build().unwrap();
        assert_backends_identical(&chip, &q, &plan);
        // The clean oracle sees the duplicates tie exactly; sanity-check
        // the duplicated layout did what the test needs.
        let clean = chip.clean_execute(&q, &plan);
        assert!(clean
            .windows(2)
            .any(|w| w[0].score == w[1].score), "corpus should be tie-heavy");
    }
}

#[test]
fn batch_identical_across_backends_and_shapes() {
    let base = db(512, 128, 10, QuantScheme::Int8);
    let chip = DircChip::build(cfg(128, 4, 8), &base);
    let mut rng = Pcg::new(11);
    let queries: Vec<Vec<i8>> =
        (0..12).map(|_| rand_query(128, QuantScheme::Int8, &mut rng)).collect();
    let pool = Arc::new(ThreadPool::new(4));
    let serial = QueryPlan::topk(10).seed(21).build().unwrap();
    let pooled = QueryPlan::topk(10).seed(21).pool(pool).build().unwrap();
    let walk = chip.execute_batch(&queries, &serial.with_backend(ScoreBackend::Walk));
    let packed = chip.execute_batch(&queries, &pooled.with_backend(ScoreBackend::Packed));
    assert_eq!(walk.len(), packed.len());
    for (w, p) in walk.iter().zip(&packed) {
        assert_eq!(w.topk, p.topk);
        assert_eq!(w.stats.sense, p.stats.sense);
        assert_eq!(w.stats.cycles, p.stats.cycles);
        assert_eq!(w.stats.energy_j.to_bits(), p.stats.energy_j.to_bits());
    }
}

// ---------------------------------------------------------------------
// Mutations: the planes must track every write path.
// ---------------------------------------------------------------------

/// Clean packed scores of every core must equal the element walk after
/// arbitrary mutations — the lockstep invariant of the plane mirror.
fn assert_planes_in_lockstep(chip: &DircChip, q: &[i8]) {
    let qp = chip.pack_query(q);
    let mut out = Vec::new();
    for (c, core) in chip.cores().iter().enumerate() {
        core.macro_().clean_scores_packed_into(&qp, &mut out);
        assert_eq!(out, core.macro_().clean_scores(q), "core {c} planes drifted");
    }
}

#[test]
fn planes_track_add_update_delete() {
    let base = db(200, 128, 12, QuantScheme::Int8);
    let extra = db(10, 128, 13, QuantScheme::Int8);
    let mut chip = DircChip::build(cfg(128, 2, 8), &base);
    let mut rng = Pcg::new(14);
    let mut qgen = Pcg::new(15);

    let payload = |src: &Quantized, i: usize| DocPayload {
        values: src.row(i).to_vec(),
        norm: src.norms[i],
    };

    // Append path: fresh docs extend the planes.
    let (ids, _) = chip
        .add_docs(&(0..4).map(|i| payload(&extra, i)).collect::<Vec<_>>(), &mut rng)
        .unwrap();
    assert_eq!(ids, vec![200, 201, 202, 203]);
    let q = rand_query(128, QuantScheme::Int8, &mut qgen);
    assert_planes_in_lockstep(&chip, &q);
    assert_backends_identical(&chip, &q, &QueryPlan::topk(10).seed(1).build().unwrap());

    // In-place update: the touched doc's plane block re-derives.
    chip.update_docs(&[(42, payload(&extra, 4)), (7, payload(&extra, 5))], &mut rng)
        .unwrap();
    let q = rand_query(128, QuantScheme::Int8, &mut qgen);
    assert_planes_in_lockstep(&chip, &q);
    assert_backends_identical(&chip, &q, &QueryPlan::topk(10).seed(2).build().unwrap());

    // Delete: tombstones only — the stale planes are still scored (the
    // walk is positional) and filtered by `live`, on both backends.
    chip.delete_docs(&[201, 3]);
    let q = rand_query(128, QuantScheme::Int8, &mut qgen);
    assert_planes_in_lockstep(&chip, &q);
    let plan = QueryPlan::topk(50).seed(3).build().unwrap();
    assert_backends_identical(&chip, &q, &plan);
    let out = chip.execute(&q, &plan);
    assert!(out.topk.iter().all(|d| d.doc_id != 201 && d.doc_id != 3));

    // Slot reuse: the next add reprograms a tombstoned slot in place.
    let (ids, _) = chip.add_docs(&[payload(&extra, 6)], &mut rng).unwrap();
    assert_eq!(ids, vec![204]);
    let q = rand_query(128, QuantScheme::Int8, &mut qgen);
    assert_planes_in_lockstep(&chip, &q);
    assert_backends_identical(&chip, &q, &QueryPlan::topk(10).seed(4).build().unwrap());
}

// ---------------------------------------------------------------------
// Flip injection: a sensed flip IS a plane XOR.
// ---------------------------------------------------------------------

#[test]
fn sensed_flips_equal_plane_toggles() {
    // Stressed corner so the sense pass reliably produces flips.
    let base = db(300, 128, 16, QuantScheme::Int8);
    let chip = DircChip::build(
        ChipConfig {
            variation: VariationModel { corner: 2.5, ..VariationModel::default() },
            ..cfg(128, 1, 8)
        },
        &base,
    );
    let core = &chip.cores()[0];
    let mut rng = Pcg::new(17);
    let q = rand_query(128, QuantScheme::Int8, &mut rng);
    let qp = chip.pack_query(&q);

    let mut flips_seen = 0usize;
    for nonce in 0..20u64 {
        let (flips, _) = chip.run_core_sense(0, nonce);
        flips_seen += flips.len();

        // Route 1: the correction path the query hot path runs (clean
        // packed scores + exact per-flip deltas).
        let mut corrected = Vec::new();
        let mut r = DircChip::core_stream(nonce, 0);
        core.macro_().sensed_scores_packed_into(&q, &qp, &mut r, &mut corrected);

        // Route 2: the reference cell walk.
        let mut r = DircChip::core_stream(nonce, 0);
        let (walked, _) = core.macro_().sensed_scores(&q, &mut r);
        assert_eq!(corrected, walked, "nonce {nonce}");

        // Route 3: physically XOR every flip into a clone of the packed
        // planes and re-score — the flip-injection contract itself.
        let mut toggled: PackedPlanes = core.macro_().packed_planes().clone();
        for f in &flips {
            toggled.toggle_bit(f.doc as usize, f.elem as usize, f.bit as usize);
        }
        let mut xor_scores = Vec::new();
        toggled.score_into(&qp, &mut xor_scores);
        assert_eq!(xor_scores, walked, "plane XOR diverged at nonce {nonce}");
    }
    assert!(
        flips_seen > 0,
        "stressed corner produced no flips in 20 nonces — the contract went untested"
    );
}
