//! Integration tests for the trace-driven load harness: trace
//! determinism end-to-end through the queueing model, exact latency
//! composition on a hand-built schedule, and a live replay smoke test
//! through the full coordinator with per-tenant tail accounting.

use std::sync::Arc;
use std::time::Duration;

use dirc_rag::coordinator::batcher::BatchPolicy;
use dirc_rag::coordinator::{
    Coordinator, CoordinatorConfig, Engine, SimEngine, TenantSpec,
};
use dirc_rag::dirc::chip::ChipConfig;
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::util::rng::Pcg;
use dirc_rag::workload::{
    queueing, runner, EventKind, QueueModelConfig, Trace, TraceConfig, TraceEvent,
};

fn db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    quantize(&fp, n, dim, QuantScheme::Int8)
}

/// The whole pipeline — trace generation through queueing-model
/// percentiles — is a pure function of the seed: two runs agree bit for
/// bit, and a different seed diverges.
#[test]
fn trace_and_model_are_reproducible_end_to_end() {
    let tcfg = TraceConfig {
        n_queries: 2000,
        distinct_queries: 64,
        n_docs: 256,
        tenant_mix: vec![0.8, 0.2],
        mutate_every: 250,
        storm_mutations: 5,
        target_qps: 150_000.0,
        seed: 0xFEED,
        ..TraceConfig::default()
    };
    let service: Vec<f64> = (0..64).map(|i| 1.5e-6 + i as f64 * 2e-8).collect();
    let qcfg = QueueModelConfig {
        workers: 2,
        weights: vec![3, 1],
        tenant_names: vec!["gold".into(), "light".into()],
        ..QueueModelConfig::default()
    };

    let a = Trace::generate(&tcfg);
    let b = Trace::generate(&tcfg);
    assert_eq!(a.digest(), b.digest(), "same seed, same schedule");
    assert_eq!(a.events.len(), b.events.len());

    let ra = queueing::simulate(&a, &service, &qcfg);
    let rb = queueing::simulate(&b, &service, &qcfg);
    assert_eq!(ra.digest(), rb.digest(), "same schedule, same percentile bits");
    for (x, y) in ra.tenants.iter().zip(&rb.tenants) {
        assert_eq!(x.p50_s.to_bits(), y.p50_s.to_bits());
        assert_eq!(x.p95_s.to_bits(), y.p95_s.to_bits());
        assert_eq!(x.p99_s.to_bits(), y.p99_s.to_bits());
    }

    let c = Trace::generate(&TraceConfig { seed: 0xFEED + 1, ..tcfg });
    assert_ne!(a.digest(), c.digest(), "seed changes the schedule");
}

/// Exact composition on a hand-built schedule: with one worker and
/// immediate flushes, the second query's sojourn is its queue wait
/// behind the first run plus its own service.
#[test]
fn queue_wait_composes_behind_a_busy_worker() {
    let trace = Trace {
        events: vec![
            TraceEvent { at_s: 0.0, kind: EventKind::Query { tenant: 0, query: 0 } },
            TraceEvent { at_s: 1e-6, kind: EventKind::Query { tenant: 0, query: 0 } },
        ],
    };
    let qcfg = QueueModelConfig {
        workers: 1,
        batch_max: 1,
        batch_max_wait_s: 1.0,
        run_max: 1,
        weights: vec![1],
        tenant_names: vec!["t".into()],
        ..QueueModelConfig::default()
    };
    let rep = queueing::simulate(&trace, &[10e-6], &qcfg);
    assert_eq!(rep.global.queries, 2);
    // q0: dispatches at 0, runs 10 µs. q1: ready at 1 µs, waits 9 µs for
    // the worker, runs 10 µs — sojourn 19 µs.
    assert!((rep.global.max_s - 19e-6).abs() < 1e-12, "{}", rep.global.max_s);
    assert!((rep.global.mean_queue_wait_s - 4.5e-6).abs() < 1e-12);
    assert!((rep.makespan_s - 20e-6).abs() < 1e-12);
    assert_eq!(rep.global.mean_batch_wait_s, 0.0, "batch_max=1 flushes instantly");
}

/// Live replay smoke: a generated mixed query/mutation trace drives the
/// real coordinator; every submission completes, per-tenant histograms
/// report monotone tails, and the served counters keep the sum-to-global
/// identity.
#[test]
fn live_replay_reports_per_tenant_tails() {
    let dim = 128;
    let n_docs = 512;
    let distinct = 32;
    let base = db(n_docs, dim, 11);
    let engine = Arc::new(SimEngine::new(
        ChipConfig { cores: 4, map_points: 25, ..ChipConfig::paper_default(dim, Metric::Mips) },
        &base,
    ));
    let ccfg = CoordinatorConfig {
        workers: 2,
        batch: BatchPolicy { sizes: vec![16], max_wait: Duration::from_millis(1) },
        tenants: vec![
            TenantSpec { name: "gold".into(), weight: 3, plan: None },
            TenantSpec { name: "light".into(), weight: 1, plan: None },
        ],
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start_sim(Arc::clone(&engine) as Arc<dyn Engine>, ccfg);

    let trace = Trace::generate(&TraceConfig {
        n_queries: 400,
        distinct_queries: distinct,
        n_docs,
        tenant_mix: vec![0.75, 0.25],
        mutate_every: 100,
        mutation_docs: 4,
        storm_mutations: 3,
        target_qps: 50_000.0,
        seed: 21,
        ..TraceConfig::default()
    });
    let mut rng = Pcg::new(33);
    let queries: Vec<Vec<f32>> =
        (0..distinct).map(|_| random_unit_rows(1, dim, &mut rng)).collect();
    let names = vec!["gold".to_string(), "light".to_string()];
    let rep = runner::replay(
        &coord,
        &trace,
        &names,
        &queries,
        dim,
        &runner::ReplayOptions::default(),
    )
    .expect("replay");

    assert_eq!(rep.queries_submitted, trace.n_queries() as u64);
    assert_eq!(rep.queries_completed, rep.queries_submitted);
    assert_eq!(rep.query_errors, 0);
    assert_eq!(
        rep.mutations_submitted + rep.mutations_skipped,
        trace.n_mutations() as u64
    );
    assert_eq!(rep.mutations_completed, rep.mutations_submitted);
    assert_eq!(rep.mutation_errors, 0);

    let snap = coord.shutdown();
    assert_eq!(snap.served, rep.queries_completed);
    assert_eq!(snap.errors, 0);
    let served_sum: u64 = snap.tenants.iter().map(|t| t.served).sum();
    assert_eq!(served_sum, snap.served, "per-tenant served sums to global");

    assert!(snap.host_latency_p50_s.is_finite() && snap.host_latency_p50_s > 0.0);
    assert!(snap.host_latency_p50_s <= snap.host_latency_p95_s);
    assert!(snap.host_latency_p95_s <= snap.host_latency_p99_s);
    for t in &snap.tenants {
        assert!(t.served > 0, "both tenants saw traffic");
        assert!(t.host_latency_p50_s.is_finite() && t.host_latency_p50_s > 0.0);
        assert!(t.host_latency_p50_s <= t.host_latency_p95_s);
        assert!(t.host_latency_p95_s <= t.host_latency_p99_s);
    }
    let text = snap.render();
    assert!(text.contains("p99"), "render surfaces tails:\n{text}");
}
