//! Loom model checks for the three extracted concurrency protocols in
//! [`dirc_rag::util::sync`]. Compiled ONLY under
//! `RUSTFLAGS="--cfg loom"` (the gating `loom` CI lane):
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom
//! ```
//!
//! Each `loom::model` body runs once per admissible interleaving of its
//! spawned threads, so the asserts below are exhaustive over the modeled
//! schedule space — not a sampled stress test. The types under test are
//! the *production* types: `util::sync` re-exports loom primitives under
//! `cfg(loom)`, so the checked code is byte-for-byte the code the
//! serving stack runs.
#![cfg(loom)]

use dirc_rag::util::sync::{
    Arc, AtomicBool, InflightGauge, JoinCounter, MutationEpoch, Ordering, RwLock,
};
use loom::sync::Mutex;
use loom::thread;

/// ThreadPool join protocol (`util::pool`): pending is incremented
/// before jobs become runnable, each job completes exactly once via its
/// drop guard (panicking jobs tally first), and `wait_zero` returns only
/// after every registered job completed.
#[test]
fn join_counter_protocol() {
    loom::model(|| {
        let c = Arc::new(JoinCounter::new());
        // The submitter registers both jobs before they can run — the
        // same order `ThreadPool::execute` enforces (add, then enqueue).
        c.add(2);
        let ok = {
            let c = Arc::clone(&c);
            thread::spawn(move || {
                c.complete();
            })
        };
        let panicky = {
            let c = Arc::clone(&c);
            thread::spawn(move || {
                // A panicking job tallies, then its guard completes.
                c.record_panic();
                c.complete();
            })
        };
        c.wait_zero();
        // wait_zero returning means both completions are ordered before
        // this point by the pending mutex.
        assert_eq!(c.pending(), 0);
        ok.join().unwrap();
        panicky.join().unwrap();
        assert_eq!(c.panicked(), 1);
    });
}

/// Cache-epoch versus snapshot-swap ordering (`coordinator::engine`):
/// the mutator publishes the new snapshot BEFORE advancing the epoch;
/// the reader observes the epoch BEFORE reading the snapshot. A reader
/// that observed epoch `e` must read a snapshot of version `>= e` —
/// i.e. a cache entry keyed at `e` can never hold a stale snapshot's
/// answer.
#[test]
fn epoch_snapshot_swap_never_keys_stale() {
    loom::model(|| {
        let epoch = Arc::new(MutationEpoch::new());
        let snapshot = Arc::new(RwLock::new(0u64)); // snapshot version

        let writer = {
            let epoch = Arc::clone(&epoch);
            let snapshot = Arc::clone(&snapshot);
            thread::spawn(move || {
                // Swap the snapshot first...
                *snapshot.write().unwrap() = 1;
                // ...then retire the old epoch (engine.on_mutation order).
                epoch.advance();
            })
        };
        let reader = {
            let epoch = Arc::clone(&epoch);
            let snapshot = Arc::clone(&snapshot);
            thread::spawn(move || {
                // Key first, read second (engine.key order).
                let keyed_at = epoch.observe();
                let version = *snapshot.read().unwrap();
                // The invariant the cache hierarchy rests on.
                assert!(
                    version >= keyed_at,
                    "cache entry keyed at epoch {keyed_at} captured snapshot v{version}"
                );
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
        // Post-state sanity: epoch retired exactly once.
        assert_eq!(epoch.observe(), 1);
    });
}

/// Coordinator shutdown/mutation drain (`coordinator::server`): requests
/// enter the gauge at submit and exit at response; the drain loop polls
/// `current()` and is short-circuited by the stop flag. After the
/// producer is done and every request answered, the gauge must read 0
/// and nothing may be left undrained.
#[test]
fn inflight_drain_on_shutdown() {
    loom::model(|| {
        let gauge = Arc::new(InflightGauge::new());
        let queue = Arc::new(Mutex::new(Vec::<u64>::new()));
        let stop = Arc::new(AtomicBool::new(false));

        let producer = {
            let gauge = Arc::clone(&gauge);
            let queue = Arc::clone(&queue);
            thread::spawn(move || {
                for id in 0..2u64 {
                    // Submit order: enter the gauge, then enqueue —
                    // mirrors `Coordinator::submit_as`.
                    gauge.enter(1);
                    queue.lock().unwrap().push(id);
                }
            })
        };
        let worker = {
            let gauge = Arc::clone(&gauge);
            let queue = Arc::clone(&queue);
            let stop = Arc::clone(&stop);
            thread::spawn(move || {
                let mut served = 0u64;
                loop {
                    let popped = queue.lock().unwrap().pop();
                    match popped {
                        Some(_id) => {
                            // Response delivered: leave the gauge.
                            gauge.exit(1);
                            served += 1;
                        }
                        // ORDERING: SeqCst — the stop flag must not be
                        // observed before queued work that preceded it.
                        None if stop.load(Ordering::SeqCst) => break,
                        None => thread::yield_now(),
                    }
                }
                served
            })
        };

        producer.join().unwrap();
        // Drain loop (mutation admission / shutdown): poll until the
        // gauge reads zero, then raise stop.
        while gauge.current() > 0 {
            thread::yield_now();
        }
        stop.store(true, Ordering::SeqCst);
        let served = worker.join().unwrap();
        assert_eq!(served, 2, "worker dropped a queued request");
        assert_eq!(gauge.current(), 0, "gauge left unbalanced after drain");
    });
}
