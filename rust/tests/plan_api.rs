//! Golden-vector equivalence net for the `QueryPlan` execution API.
//!
//! The variant matrix (`query`/`query_on`/`query_opt`/`query_batch`/
//! `query_batch_opt`, the `sense_pass*` family, `Engine::retrieve*`,
//! `submit`/`submit_opt`) was collapsed into plan-driven entry points.
//! These tests pin that the collapse changed **nothing observable**:
//!
//! * a reference implementation of the pre-plan serial walk — rebuilt
//!   verbatim from the public primitives the old variants were made of
//!   (`macro_mask` -> nonce -> `run_core_query` per core ->
//!   `finish_query_pruned`) — must match `execute` bit-for-bit, for
//!   every rng policy, prune policy, serial and pooled, on smooth and
//!   tie-heavy score distributions, and through mutate-then-query
//!   schedules;
//! * `execute_batch` must equal the serial stream of single-query
//!   calls (the old `query_batch == loop of query` contract, restated
//!   in nonce terms);
//! * plan validation rejects what the old ad-hoc checks rejected, with
//!   typed errors;
//! * the clean oracle under a probing plan equals the clean exhaustive
//!   ranking restricted to the probed macros.

use std::sync::Arc;

use dirc_rag::coordinator::{Coordinator, CoordinatorConfig, Engine, Query, SimEngine};
use dirc_rag::dirc::chip::{ChipConfig, CoreOutcome, DircChip, QueryStats};
use dirc_rag::dirc::macro_::SenseStats;
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::{Exec, PlanError, QueryPlan, RngPolicy, StatsDetail};
use dirc_rag::retrieval::quant::{quantize, random_unit_rows, QuantScheme, Quantized};
use dirc_rag::retrieval::score::{norm_i8, Metric};
use dirc_rag::retrieval::topk::ScoredDoc;
use dirc_rag::retrieval::Prune;
use dirc_rag::util::pool::ThreadPool;
use dirc_rag::util::rng::Pcg;

// ---------------------------------------------------------------------
// The reference path: the pre-plan serial walk, captured from the old
// variants before their deletion. Any change to `execute`'s semantics
// shows up as a diff against this.

/// Zero-cost outcome of a prefilter-skipped macro (the old variants'
/// `skipped_outcome`).
fn skipped(c: usize) -> CoreOutcome {
    CoreOutcome {
        core: c,
        local_topk: Vec::new(),
        stats: SenseStats::default(),
        used_slots: 0,
        max_column_resenses: 0,
        n_docs: 0,
        skipped: true,
    }
}

/// The old `query_opt(q, k, prune, rng, 1)` body: mask before nonce,
/// one nonce drawn from the caller's stream, per-core serial walk,
/// deterministic reduction.
fn reference_query(
    chip: &DircChip,
    q: &[i8],
    k: usize,
    prune: Prune,
    rng: &mut Pcg,
) -> (Vec<ScoredDoc>, QueryStats) {
    let mask = chip.macro_mask(q, prune);
    let qnonce = rng.next_u64();
    let q_norm = norm_i8(q);
    let outcomes: Vec<CoreOutcome> = (0..chip.cores().len())
        .map(|c| match &mask {
            Some(m) if !m[c] => skipped(c),
            _ => chip.run_core_query(c, q, q_norm, k, qnonce),
        })
        .collect();
    chip.finish_query_pruned(outcomes, k, mask.is_some())
}

fn assert_stats_identical(a: &QueryStats, b: &QueryStats, ctx: &str) {
    assert_eq!(a.sense, b.sense, "{ctx}: sense stats");
    assert_eq!(a.cycles, b.cycles, "{ctx}: cycles");
    assert_eq!(a.work_cycles, b.work_cycles, "{ctx}: work cycles");
    assert_eq!(a.macros_sensed, b.macros_sensed, "{ctx}: macros sensed");
    assert_eq!(a.macros_skipped, b.macros_skipped, "{ctx}: macros skipped");
    assert_eq!(a.docs_scored, b.docs_scored, "{ctx}: docs scored");
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{ctx}: latency bits");
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{ctx}: energy bits");
}

fn assert_ranking_identical(a: &[ScoredDoc], b: &[ScoredDoc], ctx: &str) {
    assert_eq!(a, b, "{ctx}: ranking");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.score.to_bits(), y.score.to_bits(), "{ctx}: score bits");
    }
}

fn unit_db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let fp = random_unit_rows(n, dim, &mut rng);
    quantize(&fp, n, dim, QuantScheme::Int8)
}

/// {-1, 0, 1}-valued database: integer scores collide constantly, the
/// distribution that stresses tie-breaking across merges.
fn tie_heavy_db(n: usize, dim: usize, seed: u64) -> Quantized {
    let mut rng = Pcg::new(seed);
    let values: Vec<i8> = (0..n * dim).map(|_| rng.int_in(-1, 1) as i8).collect();
    let norms: Vec<f32> = (0..n)
        .map(|i| norm_i8(&values[i * dim..(i + 1) * dim]) as f32)
        .collect();
    Quantized { scheme: QuantScheme::Int8, n, dim, values, scale: 1.0, norms }
}

fn plain_chip(db: &Quantized, cores: usize, metric: Metric) -> DircChip {
    let cfg = ChipConfig {
        cores,
        map_points: 40,
        ..ChipConfig::paper_default(db.dim, metric)
    };
    DircChip::build(cfg, db)
}

fn clustered_chip(db: &Quantized, cores: usize, n_clusters: usize) -> DircChip {
    let cfg = ChipConfig {
        cores,
        map_points: 40,
        cluster: ClusterPolicy { n_clusters, nprobe: 2, kmeans_iters: 6 },
        ..ChipConfig::paper_default(db.dim, Metric::Mips)
    };
    DircChip::build(cfg, db)
}

fn rand_query(dim: usize, lo: i64, hi: i64, seed: u64) -> Vec<i8> {
    let mut rng = Pcg::new(seed);
    (0..dim).map(|_| rng.int_in(lo, hi) as i8).collect()
}

// ---------------------------------------------------------------------
// execute vs the reference walk.

/// `Seeded(s)` executes exactly like the old API called with a fresh
/// `&mut Pcg::new(s)` — across metrics, prune policies, serial and
/// pooled, smooth and tie-heavy scores.
#[test]
fn execute_matches_reference_under_seeded_policy() {
    let pool = Arc::new(ThreadPool::new(4));
    for (label, db) in [
        ("unit-rows", unit_db(420, 128, 11)),
        ("tie-heavy", tie_heavy_db(420, 128, 12)),
    ] {
        for metric in [Metric::Mips, Metric::Cosine] {
            let chip = plain_chip(&db, 4, metric);
            for seed in 0..3u64 {
                let q = rand_query(128, -128, 127, 300 + seed);
                let mut ref_rng = Pcg::new(seed);
                let (want_top, want_stats) =
                    reference_query(&chip, &q, 10, Prune::Default, &mut ref_rng);
                for exec in [Exec::Serial, Exec::Pool(Arc::clone(&pool))] {
                    let plan =
                        QueryPlan::topk(10).seed(seed).exec(exec.clone()).build().unwrap();
                    let got = chip.execute(&q, &plan);
                    let ctx = format!("{label} {metric:?} seed {seed} {exec:?}");
                    assert_ranking_identical(&got.topk, &want_top, &ctx);
                    assert_stats_identical(&got.stats, &want_stats, &ctx);
                }
            }
        }
    }
    assert_eq!(pool.panicked(), 0);
}

/// `Nonce(x)` (the streaming contract) uses the caller's draw verbatim:
/// hoisting `rng.next_u64()` into the plan reproduces the old
/// shared-stream call sequence bit-for-bit, including across calls.
#[test]
fn execute_matches_reference_under_stream_policy() {
    let db = unit_db(400, 128, 21);
    let chip = plain_chip(&db, 4, Metric::Cosine);
    let base = QueryPlan::topk(8).build().unwrap();
    // One shared stream driving five consecutive queries, exactly as a
    // pre-plan caller would have passed `&mut rng` five times.
    let mut ref_rng = Pcg::new(77);
    let mut plan_rng = Pcg::new(77);
    for qi in 0..5u64 {
        let q = rand_query(128, -128, 127, 500 + qi);
        let (want_top, want_stats) =
            reference_query(&chip, &q, 8, Prune::Default, &mut ref_rng);
        let got = chip.execute(&q, &base.with_stream(&mut plan_rng));
        let ctx = format!("stream query {qi}");
        assert_ranking_identical(&got.topk, &want_top, &ctx);
        assert_stats_identical(&got.stats, &want_stats, &ctx);
    }
    // Both streams are left in the same position: one draw per query.
    assert_eq!(ref_rng.next_u64(), plan_rng.next_u64());
}

/// Pruned plans match the reference walk under every policy, and the
/// full-probe plan is bit-identical to the exhaustive one.
#[test]
fn pruned_execute_matches_reference_and_full_probe_is_exhaustive() {
    let db = unit_db(480, 128, 31);
    let chip = clustered_chip(&db, 4, 8);
    let pool = Arc::new(ThreadPool::new(4));
    for seed in 0..3u64 {
        let q = rand_query(128, -128, 127, 700 + seed);
        for prune in [Prune::None, Prune::Default, Prune::Probe(1), Prune::Probe(8)] {
            let mut ref_rng = Pcg::new(seed);
            let (want_top, want_stats) = reference_query(&chip, &q, 12, prune, &mut ref_rng);
            for exec in [Exec::Serial, Exec::Pool(Arc::clone(&pool))] {
                let plan = QueryPlan::topk(12)
                    .seed(seed)
                    .prune(prune)
                    .exec(exec.clone())
                    .build()
                    .unwrap();
                let got = chip.execute(&q, &plan);
                let ctx = format!("seed {seed} {prune:?} {exec:?}");
                assert_ranking_identical(&got.topk, &want_top, &ctx);
                assert_stats_identical(&got.stats, &want_stats, &ctx);
            }
        }
        // Full probe == exhaustive, bit for bit (census included).
        let base = QueryPlan::topk(12).seed(seed).build().unwrap();
        let full = chip.execute(&q, &base.with_prune(Prune::None).unwrap());
        let probe_all = chip.execute(&q, &base.with_prune(Prune::Probe(8)).unwrap());
        assert_ranking_identical(&full.topk, &probe_all.topk, "full-probe");
        assert_stats_identical(&full.stats, &probe_all.stats, "full-probe");
    }
}

/// The mask never consumes rng: plans differing only in `prune` sense
/// with identical flips on the cores both run (the old "caller rng
/// position is policy-independent" guarantee, restated).
#[test]
fn nonce_stream_is_prune_policy_independent() {
    let db = unit_db(480, 128, 41);
    let chip = clustered_chip(&db, 4, 8);
    let q = rand_query(128, -128, 127, 900);
    let base = QueryPlan::topk(10).seed(5).build().unwrap();
    let full = chip.execute(&q, &base.with_prune(Prune::None).unwrap());
    let pruned = chip.execute(&q, &base.with_prune(Prune::Probe(1)).unwrap());
    // Every pruned result must appear in the exhaustive ranking with
    // the same score bits (same flips on the sensed cores).
    for d in &pruned.topk {
        let twin = full.topk.iter().find(|f| f.doc_id == d.doc_id);
        if let Some(twin) = twin {
            assert_eq!(twin.score.to_bits(), d.score.to_bits(), "doc {}", d.doc_id);
        }
    }
}

// ---------------------------------------------------------------------
// execute_batch vs the serial stream.

/// `execute_batch` equals the serial stream of `execute` calls over the
/// plan's nonce stream — serial and pooled, pruned and exhaustive,
/// tie-heavy included (the old `query_batch == loop of query` golden).
#[test]
fn execute_batch_matches_serial_stream() {
    let pool = Arc::new(ThreadPool::new(4));
    for (label, db) in [
        ("unit-rows", unit_db(512, 128, 51)),
        ("tie-heavy", tie_heavy_db(512, 128, 52)),
    ] {
        let chip = clustered_chip(&db, 4, 8);
        let queries: Vec<Vec<i8>> =
            (0..9).map(|i| rand_query(128, -3, 3, 1000 + i)).collect();
        for prune in [Prune::None, Prune::Default, Prune::Probe(8)] {
            let plan = QueryPlan::topk(12).seed(84).prune(prune).build().unwrap();
            // The serial stream: one execute per query, nonce i of the
            // plan's stream (exactly what the batch must reproduce).
            let nonces = plan.nonces(queries.len());
            let want: Vec<_> = queries
                .iter()
                .zip(&nonces)
                .map(|(q, &nonce)| chip.execute(q, &plan.with_nonce(nonce)))
                .collect();
            for exec in [Exec::Serial, Exec::Pool(Arc::clone(&pool))] {
                let got = chip.execute_batch(&queries, &plan.with_exec(exec.clone()));
                assert_eq!(got.len(), want.len());
                for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
                    let ctx = format!("{label} {prune:?} {exec:?} query {qi}");
                    assert_ranking_identical(&g.topk, &w.topk, &ctx);
                    assert_stats_identical(&g.stats, &w.stats, &ctx);
                }
            }
        }
    }
    assert_eq!(pool.panicked(), 0);
}

/// Batch edge cases: empty and single-query batches.
#[test]
fn execute_batch_empty_and_single() {
    let db = unit_db(200, 128, 61);
    let chip = plain_chip(&db, 2, Metric::Mips);
    let plan = QueryPlan::topk(5).seed(2).build().unwrap();
    assert!(chip.execute_batch(&[], &plan).is_empty());
    let q = rand_query(128, -128, 127, 1100);
    let want = chip.execute(&q, &plan);
    let got = chip.execute_batch(std::slice::from_ref(&q), &plan);
    assert_eq!(got.len(), 1);
    assert_ranking_identical(&got[0].topk, &want.topk, "batch of one");
    assert_stats_identical(&got[0].stats, &want.stats, "batch of one");
}

// ---------------------------------------------------------------------
// Mutate-then-query schedules (streaming rng across corpus versions).

/// Two identical chips, the same mutation stream; between rounds the
/// reference walk (shared caller rng) and the plan path (stream-hoisted
/// nonces) must stay bit-identical — the old mutate-then-query golden,
/// restated for plans.
#[test]
fn mutate_then_query_schedule_matches_reference() {
    use dirc_rag::dirc::chip::DocPayload;

    let (n, dim) = (400, 128);
    let db = unit_db(n, dim, 71);
    let mut chip_ref = clustered_chip(&db, 4, 8);
    let mut chip_plan = clustered_chip(&db, 4, 8);

    let extra = unit_db(18, dim, 72);
    let payload =
        |i: usize| DocPayload { values: extra.row(i).to_vec(), norm: extra.norms[i] };

    let mut w_ref = Pcg::new(73);
    let mut w_plan = Pcg::new(73);
    let mut q_ref = Pcg::new(74);
    let mut q_plan = Pcg::new(74);
    let base = QueryPlan::topk(10).build().unwrap();
    let mut next_extra = 0usize;

    for round in 0..3usize {
        for prune in [Prune::Default, Prune::Probe(5)] {
            let q = rand_query(dim, -128, 127, 1200 + round as u64);
            let (want_top, want_stats) =
                reference_query(&chip_ref, &q, 10, prune, &mut q_ref);
            let plan = base.with_prune(prune).unwrap().with_stream(&mut q_plan);
            let got = chip_plan.execute(&q, &plan);
            let ctx = format!("round {round} {prune:?}");
            assert_ranking_identical(&got.topk, &want_top, &ctx);
            assert_stats_identical(&got.stats, &want_stats, &ctx);
        }

        // Identical mutation burst on both chips.
        let adds: Vec<DocPayload> = (0..4).map(|i| payload(next_extra + i)).collect();
        next_extra += 4;
        let (ids_a, _) = chip_ref.add_docs(&adds, &mut w_ref).expect("add");
        let (ids_b, _) = chip_plan.add_docs(&adds, &mut w_plan).expect("add");
        assert_eq!(ids_a, ids_b, "round {round}: assigned ids diverged");

        let upd: Vec<(u64, DocPayload)> = (0..2)
            .map(|i| ((round * 29 + i * 11) as u64 % n as u64, payload(next_extra + i)))
            .collect();
        next_extra += 2;
        chip_ref.update_docs(&upd, &mut w_ref).expect("update");
        chip_plan.update_docs(&upd, &mut w_plan).expect("update");

        let dels = [(round * 37 + 5) as u64 % n as u64];
        chip_ref.delete_docs(&dels);
        chip_plan.delete_docs(&dels);
        assert_eq!(chip_ref.n_docs(), chip_plan.n_docs(), "round {round}: corpus size");
    }
}

// ---------------------------------------------------------------------
// Engine and coordinator layers.

/// `Engine::retrieve` / `retrieve_batch` on a `SimEngine` equal the
/// chip-level plan execution — serial engine, pooled engine, and the
/// explicitly-serial plan on a pooled engine.
#[test]
fn engine_layer_matches_chip_layer() {
    let db = unit_db(384, 128, 81);
    let mk_cfg = || ChipConfig {
        cores: 4,
        map_points: 40,
        ..ChipConfig::paper_default(128, Metric::Cosine)
    };
    let serial = SimEngine::new(mk_cfg(), &db);
    let pool = Arc::new(ThreadPool::new(4));
    let pooled = SimEngine::with_pool(mk_cfg(), &db, Some(Arc::clone(&pool)));
    let reference = DircChip::build(mk_cfg(), &db);

    let queries: Vec<Vec<i8>> = (0..6).map(|i| rand_query(128, -128, 127, 1300 + i)).collect();
    for (qi, q) in queries.iter().enumerate() {
        let plan = QueryPlan::topk(5).seed(qi as u64).build().unwrap();
        let mut ref_rng = Pcg::new(qi as u64);
        let (want_top, want_stats) =
            reference_query(&reference, q, 5, Prune::Default, &mut ref_rng);
        for (engine, label) in
            [(&serial as &dyn Engine, "serial"), (&pooled as &dyn Engine, "pooled")]
        {
            let got = engine.retrieve(q, &plan);
            let ctx = format!("{label} engine query {qi}");
            assert_ranking_identical(&got.topk, &want_top, &ctx);
            assert_stats_identical(&got.stats, &want_stats, &ctx);
        }
        let got = pooled.retrieve(q, &plan.with_exec(Exec::Serial));
        assert_ranking_identical(&got.topk, &want_top, "forced-serial on pooled engine");
    }

    // Batch: both engines against the chip's batch (already pinned to
    // the serial stream above).
    let plan = QueryPlan::topk(5).seed(99).build().unwrap();
    let want = reference.execute_batch(&queries, &plan);
    for (engine, label) in
        [(&serial as &dyn Engine, "serial"), (&pooled as &dyn Engine, "pooled")]
    {
        let got = engine.retrieve_batch(&queries, &plan);
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            let ctx = format!("{label} engine batch query {qi}");
            assert_ranking_identical(&g.topk, &w.topk, &ctx);
            assert_stats_identical(&g.stats, &w.stats, &ctx);
        }
    }
    assert_eq!(pool.panicked(), 0);
}

/// `Coordinator::submit(query, plan)` honours the plan end-to-end: `k`
/// sizes the response, per-request prune policies group and dispatch
/// correctly, and mixed-plan bursts all come back right.
#[test]
fn submit_carries_plan_end_to_end() {
    let db = unit_db(256, 128, 91);
    let cfg = ChipConfig {
        cores: 4,
        map_points: 40,
        cluster: ClusterPolicy { n_clusters: 8, nprobe: 4, kmeans_iters: 6 },
        ..ChipConfig::paper_default(128, Metric::Cosine)
    };
    let engine = Arc::new(SimEngine::new(cfg, &db));
    let chip = engine.chip();
    let coord = Coordinator::start_sim(engine, CoordinatorConfig::default());

    let emb_of = |i: usize| -> Vec<f32> {
        db.row(i).iter().map(|&v| v as f32 * db.scale).collect()
    };
    // A burst mixing k and prune — workers must group by (k, prune) and
    // still answer every request with its own plan's k.
    let mut rxs = Vec::new();
    for i in 0..24usize {
        let k = if i % 2 == 0 { 5 } else { 3 };
        let plan = match i % 3 {
            0 => QueryPlan::topk(k).build().unwrap(),
            1 => QueryPlan::topk(k).nprobe(2).build().unwrap(),
            _ => QueryPlan::topk(k).prune(Prune::None).build().unwrap(),
        };
        // Whether doc i's macro survives this plan's prefilter is
        // deterministic — compute it the way the ingest thread will
        // (same quantisation), so the top-1 assertion below never
        // hinges on a legitimately-pruned self document.
        let emb = emb_of(i);
        let q_int = quantize(&emb, 1, emb.len(), QuantScheme::Int8).values;
        let self_probed = match chip.macro_mask(&q_int, plan.prune()) {
            None => true,
            Some(mask) => chip
                .cores()
                .iter()
                .enumerate()
                .any(|(c, core)| mask[c] && core.find_doc(i as u64).is_some()),
        };
        let (id, rx) = coord.submit(Query::Embedding(emb), plan).unwrap();
        rxs.push((id, i, k, self_probed, rx));
    }
    for (id, i, k, self_probed, rx) in rxs {
        let resp = rx.recv().expect("query answered");
        assert_eq!(resp.id, id);
        assert_eq!(resp.topk.len(), k, "request {i} must honour its plan's k");
        if self_probed {
            // A probed corpus row is its own best match under cosine.
            assert_eq!(resp.topk[0].doc_id, i as u64, "request {i}");
        }
    }
    let snap = coord.shutdown();
    assert_eq!(snap.served, 24);
    assert_eq!(snap.errors, 0);
}

// ---------------------------------------------------------------------
// Clean oracle under plans.

/// Clean pruned == clean exhaustive restricted to the probed macros
/// (ideal-readout semantics survive the `clean_execute` collapse).
#[test]
fn clean_pruned_equals_clean_exhaustive_restricted() {
    let db = unit_db(480, 128, 101);
    let chip = clustered_chip(&db, 4, 8);
    let n = chip.n_docs();
    for seed in 0..6u64 {
        let q = rand_query(128, -128, 127, 1500 + seed);
        for nprobe in [1usize, 2, 5] {
            let pruned = chip.clean_execute(
                &q,
                &QueryPlan::topk(10).nprobe(nprobe).build().unwrap(),
            );
            let full = chip.clean_execute(
                &q,
                &QueryPlan::topk(n).prune(Prune::None).build().unwrap(),
            );
            let Some(mask) = chip.macro_mask(&q, Prune::Probe(nprobe)) else {
                // Degenerate mask: the pruned call ran exhaustively.
                assert_eq!(pruned, full[..10.min(full.len())]);
                continue;
            };
            let probed: std::collections::HashSet<u64> = chip
                .cores()
                .iter()
                .enumerate()
                .filter(|(c, _)| mask[*c])
                .flat_map(|(_, core)| {
                    core.doc_ids()
                        .iter()
                        .zip(core.live())
                        .filter(|(_, &l)| l)
                        .map(|(&id, _)| id)
                })
                .collect();
            let want: Vec<ScoredDoc> = full
                .iter()
                .filter(|d| probed.contains(&d.doc_id))
                .take(10)
                .cloned()
                .collect();
            assert_eq!(pruned, want, "seed {seed} nprobe {nprobe}");
        }
    }
}

// ---------------------------------------------------------------------
// Sense path and stats detail.

/// `sense_execute` flips equal the functional path's flips (same nonce,
/// same streams), serial == pooled, and the returned mask matches
/// `macro_mask`.
#[test]
fn sense_execute_consistent_serial_and_pooled() {
    let db = unit_db(400, 128, 111);
    let chip = clustered_chip(&db, 4, 8);
    let pool = Arc::new(ThreadPool::new(4));
    for seed in 0..3u64 {
        let q = rand_query(128, -128, 127, 1600 + seed);
        for prune in [Prune::None, Prune::Probe(1)] {
            let plan = QueryPlan::topk(10).seed(seed).prune(prune).build().unwrap();
            let serial = chip.sense_execute(&q, &plan);
            let pooled = chip.sense_execute(&q, &plan.with_exec(Exec::Pool(Arc::clone(&pool))));
            let ctx = format!("seed {seed} {prune:?}");
            assert_eq!(serial.flips, pooled.flips, "{ctx}: flips");
            assert_stats_identical(&serial.stats, &pooled.stats, &ctx);
            assert_eq!(serial.mask, pooled.mask, "{ctx}: mask");
            assert_eq!(serial.mask, chip.macro_mask(&q, prune), "{ctx}: mask source");
            // Masked-out macros contribute no flips.
            if let Some(m) = &serial.mask {
                for (c, sensed) in m.iter().enumerate() {
                    if !sensed {
                        assert!(serial.flips[c].is_empty(), "{ctx}: core {c}");
                    }
                }
            }
        }
    }
    assert_eq!(pool.panicked(), 0);
}

/// `StatsDetail::Counters` never changes results or counters, only
/// zeroes the model fields.
#[test]
fn counters_detail_equivalence() {
    let db = unit_db(320, 128, 121);
    let chip = plain_chip(&db, 4, Metric::Mips);
    let q = rand_query(128, -128, 127, 1700);
    let full = chip.execute(&q, &QueryPlan::topk(10).seed(9).build().unwrap());
    let lean = chip.execute(
        &q,
        &QueryPlan::topk(10).seed(9).detail(StatsDetail::Counters).build().unwrap(),
    );
    assert_ranking_identical(&full.topk, &lean.topk, "counters detail");
    assert_eq!(full.stats.sense, lean.stats.sense);
    assert_eq!(full.stats.docs_scored, lean.stats.docs_scored);
    assert_eq!(
        (full.stats.macros_sensed, full.stats.macros_skipped),
        (lean.stats.macros_sensed, lean.stats.macros_skipped)
    );
    assert_eq!((lean.stats.cycles, lean.stats.work_cycles), (0, 0));
    assert_eq!(lean.stats.energy_j, 0.0);
    assert_eq!(lean.stats.latency_s, 0.0);
}

// ---------------------------------------------------------------------
// Validation.

#[test]
fn plan_validation_typed_errors() {
    assert_eq!(QueryPlan::topk(0).build().unwrap_err(), PlanError::ZeroK);
    assert_eq!(QueryPlan::topk(5).nprobe(0).build().unwrap_err(), PlanError::ZeroNprobe);
    assert_eq!(
        QueryPlan::topk(100).corpus_hint(50).build().unwrap_err(),
        PlanError::KBeyondCorpus { k: 100, corpus: 50 }
    );
    // Errors render human-readably (they surface through anyhow in the
    // config binding and CLI).
    assert!(PlanError::ZeroK.to_string().contains("k"));
    assert!(
        PlanError::KBeyondCorpus { k: 3, corpus: 2 }.to_string().contains("corpus"),
    );
}

/// The plan's rng policy derivations are pinned: `Seeded(s)` is the
/// `Pcg::new(s)` stream, `Nonce(x)` is verbatim-then-`Pcg::new(x)` — so
/// the nonce contract can never silently change between PRs.
#[test]
fn rng_policy_derivations_pinned() {
    let plan = QueryPlan::topk(1).seed(42).build().unwrap();
    let mut r = Pcg::new(42);
    assert_eq!(plan.nonces(3), vec![r.next_u64(), r.next_u64(), r.next_u64()]);

    let plan = QueryPlan::topk(1).nonce(7).build().unwrap();
    assert_eq!(plan.rng(), RngPolicy::Nonce(7));
    let mut cont = Pcg::new(7);
    assert_eq!(plan.nonces(2), vec![7, cont.next_u64()]);
}
