//! End-to-end retrieval-precision regression net.
//!
//! The paper's key quality claim is that the hardware path (detect on,
//! error-aware remap, nominal corner) *maintains* retrieval precision.
//! The `eval` CLI can show that interactively; this test pins it in the
//! suite:
//!
//! 1. **Golden determinism pin** — the full evaluation (seeded synthetic
//!    dataset -> quantise -> chip with error injection -> precision@k)
//!    is re-run from identical seeds and must reproduce bit-for-bit.
//!    Any change to the dataset generator, quantiser, error-map
//!    extraction, sensing walk or top-k machinery that shifts results
//!    trips this immediately. (The authoring environment has no Rust
//!    toolchain to mint literal golden numbers — see
//!    `.claude/skills/verify/SKILL.md` — so the pin is the reproduction
//!    itself plus the bounded windows below; a toolchain session can
//!    tighten the windows to literals by printing `run_eval`'s output.)
//! 2. **Bounded windows** — the same clean-floor and noisy-within-0.05
//!    bounds the tier-1 suite already proves for this exact dataset
//!    recipe (`tests/integration.rs::sim_engine_preserves_precision_at_
//!    nominal_corner`), extended to P@{1,5,10}.
//! 3. **Churn invariance** — after a burst of `update_docs` that
//!    re-programs 10% of the corpus through the pulse-accurate write
//!    path (same embeddings: hardware churn, no semantic change),
//!    precision@{1,5,10} must stay within 1% of the static-corpus
//!    baseline.

use dirc_rag::data::{SynthDataset, SynthParams};
use dirc_rag::dirc::chip::{ChipConfig, DircChip, DocPayload};
use dirc_rag::dirc::RemapStrategy;
use dirc_rag::eval::precision_at_k;
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::retrieval::Prune;
use dirc_rag::util::rng::Pcg;

const N_DOCS: usize = 1500;
const N_QUERIES: usize = 60;
const DIM: usize = 512;

fn dataset() -> SynthDataset {
    // Identical recipe to the proven integration-test operating point.
    let params = SynthParams {
        topics: 32,
        doc_noise: 0.6,
        rels_per_query: 1,
        extra_rel_range: 1,
        query_noise: 0.5,
        confuse: 0.8,
        aniso: 1.0,
        seed: 11,
    };
    SynthDataset::generate(N_DOCS, N_QUERIES, DIM, &params)
}

fn chip_cfg() -> ChipConfig {
    ChipConfig {
        cores: 4,
        map_points: 60,
        ..ChipConfig::paper_default(DIM, Metric::Cosine)
    }
}

/// Averaged P@{1,5,10} of the erroneous hardware path (detect on,
/// error-aware remap), retrieved at k = 10 under a seeded plan.
fn run_eval(chip: &DircChip, ds: &SynthDataset) -> (f64, f64, f64) {
    run_eval_pruned(chip, ds, Prune::None).0
}

/// [`run_eval`] under an explicit pruning policy; also returns the
/// summed work cycles and skipped-macro count across the query set.
/// Seed 13 = the nonce stream the pre-plan harness consumed from
/// `Pcg::new(13)`; both policies share it (the mask never consumes
/// query rng), so their flips are bit-identical on the sensed cores.
fn run_eval_pruned(
    chip: &DircChip,
    ds: &SynthDataset,
    prune: Prune,
) -> ((f64, f64, f64), (u64, u64)) {
    let queries: Vec<Vec<i8>> = (0..N_QUERIES)
        .map(|qi| quantize(ds.query(qi), 1, DIM, QuantScheme::Int8).values)
        .collect();
    let plan = QueryPlan::topk(10).prune(prune).seed(13).build().unwrap();
    let outs = chip.execute_batch(&queries, &plan);
    let (mut p1, mut p5, mut p10) = (0.0, 0.0, 0.0);
    let (mut work, mut skipped) = (0u64, 0u64);
    for (qi, out) in outs.iter().enumerate() {
        work += out.stats.work_cycles;
        skipped += out.stats.macros_skipped as u64;
        p1 += precision_at_k(&out.topk, &ds.qrels[qi], 1);
        p5 += precision_at_k(&out.topk, &ds.qrels[qi], 5);
        p10 += precision_at_k(&out.topk, &ds.qrels[qi], 10);
    }
    let n = N_QUERIES as f64;
    ((p1 / n, p5 / n, p10 / n), (work, skipped))
}

/// Clean-oracle P@1 (the software reference the hardware must track).
fn run_clean_p1(chip: &DircChip, ds: &SynthDataset) -> f64 {
    let oracle = QueryPlan::topk(10).prune(Prune::None).build().unwrap();
    let mut p1 = 0.0;
    for qi in 0..N_QUERIES {
        let q = quantize(ds.query(qi), 1, DIM, QuantScheme::Int8);
        let ranked = chip.clean_execute(&q.values, &oracle);
        p1 += precision_at_k(&ranked, &ds.qrels[qi], 1);
    }
    p1 / N_QUERIES as f64
}

#[test]
fn precision_at_k_pinned_and_bounded() {
    let ds = dataset();
    let db = quantize(&ds.docs, N_DOCS, DIM, QuantScheme::Int8);
    let cfg = chip_cfg();
    assert!(cfg.detect, "the regression net pins the detect-on path");
    assert_eq!(cfg.remap, RemapStrategy::ErrorAware);
    let chip = DircChip::build(cfg, &db);

    let (p1, p5, p10) = run_eval(&chip, &ds);

    // Golden determinism pin: a from-scratch rebuild reproduces every
    // bit of the evaluation.
    let chip2 = DircChip::build(chip_cfg(), &db);
    let (q1, q5, q10) = run_eval(&chip2, &ds);
    assert_eq!(p1.to_bits(), q1.to_bits(), "P@1 not reproducible");
    assert_eq!(p5.to_bits(), q5.to_bits(), "P@5 not reproducible");
    assert_eq!(p10.to_bits(), q10.to_bits(), "P@10 not reproducible");

    // Bounded windows: hardware tracks the clean oracle (the bound the
    // suite already proves for this recipe at P@1), and the ranked-list
    // identities hold (top-1 ⊆ top-5 ⊆ top-10 => hit counts monotone).
    let clean_p1 = run_clean_p1(&chip, &ds);
    assert!(clean_p1 > 0.5, "dataset too hard: clean P@1 {clean_p1}");
    assert!(
        p1 >= clean_p1 - 0.05,
        "nominal-corner errors dented precision: clean {clean_p1} noisy {p1}"
    );
    assert!(p5 * 5.0 >= p1 - 1e-9, "hits@5 < hits@1");
    assert!(p10 * 10.0 >= p5 * 5.0 - 1e-9, "hits@10 < hits@5");
    assert!(p1 > 0.0 && p1 <= 1.0 && p5 <= 1.0 && p10 <= 1.0);
}

#[test]
fn precision_survives_update_burst_within_one_percent() {
    let ds = dataset();
    let db = quantize(&ds.docs, N_DOCS, DIM, QuantScheme::Int8);
    let mut chip = DircChip::build(chip_cfg(), &db);

    let baseline = run_eval(&chip, &ds);

    // Churn burst: re-program 10% of the corpus in place through the
    // pulse-accurate write path (same quantised embeddings — hardware
    // churn without semantic drift, the contract a live index must hold).
    //
    // Scope note: this burst stays under the wear-refresh threshold, so
    // it gates the *write path* — stored-value integrity and ΣD-LUT
    // resynchronisation (a wrong LUT changes the detect/re-sense flip
    // stream and trips the 1% bound; corrupted values shift the clean
    // scores and trip it too). The error-map refresh + layout
    // re-derivation path is exercised separately by
    // `precision_survives_forced_map_refresh` below, whose bound is
    // necessarily looser (a refreshed map legitimately changes the flip
    // stream).
    let ids: Vec<u64> = (0..(N_DOCS as u64 / 10)).map(|i| (i * 7) % N_DOCS as u64).collect();
    let updates: Vec<(u64, DocPayload)> = ids
        .iter()
        .map(|&id| {
            let i = id as usize;
            (id, DocPayload { values: db.row(i).to_vec(), norm: db.norms[i] })
        })
        .collect();
    let mut wrng = Pcg::new(99);
    let stats = chip.update_docs(&updates, &mut wrng).expect("update burst");
    assert_eq!(stats.docs_updated + stats.missing_ids, updates.len());
    assert!(stats.missing_ids <= ids.len() - 100, "most ids must be resident");
    assert!(stats.write_pulses > 0, "the burst must actually program cells");
    assert!(chip.total_wear() > 0);

    let after = run_eval(&chip, &ds);
    for (k, b, a) in [
        (1, baseline.0, after.0),
        (5, baseline.1, after.1),
        (10, baseline.2, after.2),
    ] {
        assert!(
            (a - b).abs() <= 0.01 + 1e-12,
            "P@{k} drifted past 1% through corpus churn: {b} -> {a}"
        );
    }
}

// ---------------------------------------------------------------------
// Two-stage cluster-pruned retrieval: the recall/latency gate.

/// Clustering knobs of the pruned gate chip. `Prune::Default` probes the
/// configured default `nprobe` (4) of these clusters.
const PRUNE_CLUSTERS: usize = 16;

fn pruned_chip_cfg() -> ChipConfig {
    ChipConfig {
        cluster: ClusterPolicy { n_clusters: PRUNE_CLUSTERS, nprobe: 4, kmeans_iters: 8 },
        ..chip_cfg()
    }
}

/// The pinned recall gate: with the centroid prefilter live at the
/// default `nprobe`, P@{1,5,10} stays within 2% of the exhaustive path
/// on the same chip — detect on, error-aware remap, identical rng
/// streams (so the sensing-error flips are bit-identical in both arms
/// and the measured difference is purely the pruning restriction).
/// Determinism-pinned like the exhaustive gate: a from-scratch rebuild
/// (k-means included) reproduces every bit.
#[test]
fn pruned_precision_within_two_percent_of_exhaustive() {
    let ds = dataset();
    let db = quantize(&ds.docs, N_DOCS, DIM, QuantScheme::Int8);
    let cfg = pruned_chip_cfg();
    assert!(cfg.detect, "the gate pins the detect-on path");
    assert_eq!(cfg.remap, RemapStrategy::ErrorAware);
    let chip = DircChip::build(cfg, &db);
    assert!(chip.cluster_index().is_some());

    let (full, (full_work, _)) = run_eval_pruned(&chip, &ds, Prune::None);
    let (pruned, (pruned_work, skipped)) = run_eval_pruned(&chip, &ds, Prune::Default);

    // Golden determinism pin: rebuild (k-means included) -> same bits.
    let chip2 = DircChip::build(pruned_chip_cfg(), &db);
    let (pruned2, (work2, skipped2)) = run_eval_pruned(&chip2, &ds, Prune::Default);
    assert_eq!(pruned.0.to_bits(), pruned2.0.to_bits(), "pruned P@1 not reproducible");
    assert_eq!(pruned.1.to_bits(), pruned2.1.to_bits(), "pruned P@5 not reproducible");
    assert_eq!(pruned.2.to_bits(), pruned2.2.to_bits(), "pruned P@10 not reproducible");
    assert_eq!((pruned_work, skipped), (work2, skipped2), "work census not reproducible");

    // The 2% recall gate, per k.
    for (k, f, p) in [(1, full.0, pruned.0), (5, full.1, pruned.1), (10, full.2, pruned.2)] {
        assert!(
            (f - p).abs() <= 0.02 + 1e-12,
            "P@{k} drifted past 2% under default-nprobe pruning: exhaustive {f} pruned {p}"
        );
    }
    // And the prefilter must actually skip sense work to earn its keep.
    assert!(skipped > 0, "default nprobe must skip at least some macros");
    assert!(
        pruned_work < full_work,
        "pruned sense work {pruned_work} not below exhaustive {full_work}"
    );
}

/// The same 2% gate after the PR-2 churn harness: a 10% in-place update
/// burst through the pulse-accurate write path (same embeddings, so the
/// cluster routing re-stamps every doc to its existing cluster and the
/// probed sets are unchanged), then pruned-vs-exhaustive again on the
/// post-churn chip.
#[test]
fn pruned_precision_gate_survives_update_burst() {
    let ds = dataset();
    let db = quantize(&ds.docs, N_DOCS, DIM, QuantScheme::Int8);
    let mut chip = DircChip::build(pruned_chip_cfg(), &db);

    let ids: Vec<u64> = (0..(N_DOCS as u64 / 10)).map(|i| (i * 7) % N_DOCS as u64).collect();
    let updates: Vec<(u64, DocPayload)> = ids
        .iter()
        .map(|&id| {
            let i = id as usize;
            (id, DocPayload { values: db.row(i).to_vec(), norm: db.norms[i] })
        })
        .collect();
    let mut wrng = Pcg::new(99);
    let stats = chip.update_docs(&updates, &mut wrng).expect("update burst");
    assert!(stats.write_pulses > 0);

    let (full, _) = run_eval_pruned(&chip, &ds, Prune::None);
    let (pruned, (_, skipped)) = run_eval_pruned(&chip, &ds, Prune::Default);
    assert!(skipped > 0);
    for (k, f, p) in [(1, full.0, pruned.0), (5, full.1, pruned.1), (10, full.2, pruned.2)] {
        assert!(
            (f - p).abs() <= 0.02 + 1e-12,
            "post-churn P@{k} drifted past 2% under pruning: exhaustive {f} pruned {p}"
        );
    }
}

/// Churn that crosses the wear threshold: the burst forces the lazy
/// error-map re-characterisation and the error-aware layout
/// re-derivation of every touched macro, then re-evaluates. The clean
/// oracle is unchanged by a refresh (stored values are identical), so
/// the hardware path must still track it — with double the margin the
/// static-corpus suite proves, because a refreshed map legitimately
/// yields a different (same-distribution) flip stream.
#[test]
fn precision_survives_forced_map_refresh() {
    let ds = dataset();
    let db = quantize(&ds.docs, N_DOCS, DIM, QuantScheme::Int8);
    let cfg = ChipConfig {
        // Any wear at all triggers the refresh on the next mutation.
        wear_refresh_pulses: 1,
        ..chip_cfg()
    };
    let mut chip = DircChip::build(cfg, &db);

    let updates: Vec<(u64, DocPayload)> = (0..40u64)
        .map(|id| (id, DocPayload { values: db.row(id as usize).to_vec(), norm: db.norms[id as usize] }))
        .collect();
    let mut wrng = Pcg::new(101);
    // First burst marks rows stale; second one refreshes + re-lays-out.
    chip.update_docs(&updates, &mut wrng).expect("first burst");
    let stats = chip.update_docs(&updates, &mut wrng).expect("second burst");
    assert!(stats.map_rows_refreshed > 0, "burst must re-characterise the map");
    assert!(stats.layouts_rederived >= 1, "touched macros must re-derive layouts");
    assert!(chip.map_epoch() >= 1);

    let clean_p1 = run_clean_p1(&chip, &ds);
    let (p1, p5, p10) = run_eval(&chip, &ds);
    assert!(clean_p1 > 0.5, "refresh must not disturb stored values: {clean_p1}");
    assert!(
        p1 >= clean_p1 - 0.10,
        "post-refresh hardware path lost the clean oracle: clean {clean_p1} noisy {p1}"
    );
    assert!(p5 * 5.0 >= p1 - 1e-9 && p10 * 10.0 >= p5 * 5.0 - 1e-9);
}
