//! System-level property tests (the proptest-style suite): invariants of
//! routing/striping, batching, detection, correction exactness and
//! quantisation, over randomly generated configurations.

use dirc_rag::coordinator::batcher::{BatchPolicy, Batcher};
use dirc_rag::dirc::chip::{ChipConfig, DircChip, DocPayload};
use dirc_rag::retrieval::cluster::ClusterPolicy;
use dirc_rag::retrieval::plan::QueryPlan;
use dirc_rag::retrieval::Prune;
use dirc_rag::dirc::detect::DSumLut;
use dirc_rag::dirc::device::MlcLevel;
use dirc_rag::dirc::macro_::{geometric_walk, DircMacro, MacroConfig};
use dirc_rag::dirc::remap::{Layout, RemapStrategy, SLOTS_PER_CELL};
use dirc_rag::dirc::variation::VariationModel;
use dirc_rag::dirc::detect::ResensePolicy;
use dirc_rag::dirc::write::{SramFallbackModel, WriteModel};
use dirc_rag::retrieval::quant::{quantize, QuantScheme};
use dirc_rag::retrieval::score::Metric;
use dirc_rag::util::prop::{cases, forall, gen_pair, gen_usize};
use dirc_rag::util::rng::Pcg;

fn rand_docs(n: usize, dim: usize, bits: usize, seed: u64) -> Vec<i8> {
    let mut rng = Pcg::new(seed);
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    (0..n * dim).map(|_| rng.int_in(lo, hi) as i8).collect()
}

/// Striping invariant: every (doc, element) maps to exactly one physical
/// (column, word, row) and back, for random occupancy/dim/precision.
#[test]
fn prop_macro_layout_is_injective() {
    let map = VariationModel::default().extract_error_map(30, 1);
    forall(
        cases(12),
        gen_pair(gen_usize(1, 4), gen_usize(0, 2)),
        |&(fold, bits_sel)| {
            let bits = if bits_sel == 0 { 4 } else { 8 };
            let dim = fold * 128;
            let cfg = MacroConfig {
                bits,
                dim,
                detect: false,
                remap: RemapStrategy::ErrorAware,
                resense: ResensePolicy::default(),
            };
            let cap = cfg.capacity_docs();
            let n = (cap / 3).max(1);
            let docs = rand_docs(n, dim, bits, 7);
            let m = DircMacro::program(cfg, &docs, n, &map);
            // Round-trip through flips: flipping bit b of (doc, elem) and
            // materialising must change exactly that value.
            let mut rng = Pcg::new(9);
            for _ in 0..50 {
                let doc = rng.index(n) as u32;
                let elem = rng.index(dim) as u32;
                let bit = rng.index(bits) as u8;
                let val = docs[doc as usize * dim + elem as usize];
                let flip = dirc_rag::dirc::macro_::Flip {
                    doc,
                    elem,
                    bit,
                    was_one: (val >> bit) & 1 != 0,
                };
                let out = m.apply_flips_to_matrix(&[flip]);
                let mut diff = 0;
                for (i, (&a, &b)) in out.iter().zip(docs.iter()).enumerate() {
                    if a != b {
                        diff += 1;
                        if i != doc as usize * dim + elem as usize {
                            return false;
                        }
                    }
                }
                if diff != 1 {
                    return false;
                }
            }
            true
        },
    );
}

/// Correction exactness over random flip sets: clean + corrections ==
/// rescoring the flipped matrix, for arbitrary (n, dim, query).
#[test]
fn prop_score_corrections_exact() {
    let map = VariationModel { corner: 4.0, ..VariationModel::default() }
        .extract_error_map(60, 3);
    forall(cases(10), gen_usize(1, 6), |&groups| {
        let dim = 128;
        let n = groups * 64;
        let docs = rand_docs(n, dim, 8, groups as u64);
        let cfg = MacroConfig {
            bits: 8,
            dim,
            detect: false,
            remap: RemapStrategy::Interleaved,
            resense: ResensePolicy::default(),
        };
        let m = DircMacro::program(cfg, &docs, n, &map);
        let mut rng = Pcg::new(groups as u64 + 100);
        let q: Vec<i8> = (0..dim).map(|_| rng.int_in(-128, 127) as i8).collect();
        let (flips, _) = m.sense(&mut rng);
        let mut fast = m.clean_scores(&q);
        for (doc, dq) in m.score_corrections(&flips, &q) {
            fast[doc as usize] += dq;
        }
        let flipped = m.apply_flips_to_matrix(&flips);
        (0..n).all(|d| {
            let want: i64 = (0..dim).map(|j| flipped[d * dim + j] as i64 * q[j] as i64).sum();
            fast[d] == want
        })
    });
}

/// Detection soundness: a plane with an odd number of flips is always
/// caught (sum cannot be preserved).
#[test]
fn prop_odd_flip_counts_always_caught() {
    forall(
        cases(200),
        gen_pair(gen_usize(0, 64), gen_usize(0, 64)),
        |&(up, down)| {
            let lut = DSumLut::precompute(16, 8, |_, _| 64);
            let outcome = lut.classify(3, 2, up as u16, down as u16);
            if (up + down) % 2 == 1 {
                outcome == dirc_rag::dirc::detect::DetectOutcome::Caught
            } else if up + down == 0 {
                outcome == dirc_rag::dirc::detect::DetectOutcome::Clean
            } else if up == down {
                outcome == dirc_rag::dirc::detect::DetectOutcome::Escaped
            } else {
                outcome == dirc_rag::dirc::detect::DetectOutcome::Caught
            }
        },
    );
}

/// Batcher conservation: across any push/flush interleaving, every item
/// comes out exactly once and batch sizes respect the policy.
#[test]
fn prop_batcher_conserves_items() {
    forall(cases(60), gen_usize(1, 300), |&n| {
        let policy = BatchPolicy {
            sizes: vec![1, 32],
            max_wait: std::time::Duration::from_secs(3600),
        };
        let mut b = Batcher::new(policy);
        let mut out: Vec<usize> = Vec::new();
        for i in 0..n {
            b.push(i);
            if b.should_flush() {
                let batch = b.take_batch();
                if batch.is_empty() || batch.len() > 32 {
                    return false;
                }
                out.extend(batch);
            }
        }
        while !b.is_empty() {
            out.extend(b.take_batch());
        }
        out.sort_unstable();
        out == (0..n).collect::<Vec<_>>()
    });
}

/// Geometric walk == Bernoulli stream, statistically: mean count within
/// 5 sigma for random (len, p).
#[test]
fn prop_geometric_walk_unbiased() {
    forall(
        cases(20),
        gen_pair(gen_usize(100, 20_000), gen_usize(1, 200)),
        |&(len, pmil)| {
            let p = pmil as f64 / 2000.0; // up to 10%
            let mut rng = Pcg::new((len * pmil) as u64);
            let reps = 40;
            let mut total = 0usize;
            for _ in 0..reps {
                total += geometric_walk(len, p, &mut rng).len();
            }
            let mean = total as f64 / reps as f64;
            let want = len as f64 * p;
            let sigma = (len as f64 * p * (1.0 - p) / reps as f64).sqrt();
            (mean - want).abs() < 5.0 * sigma + 1.0
        },
    );
}

/// Chip routing: global top-k ids are always valid, unique, sorted by
/// score, for random db sizes and k.
#[test]
fn prop_chip_topk_wellformed() {
    let build_cache: std::cell::RefCell<Option<(usize, DircChip)>> =
        std::cell::RefCell::new(None);
    forall(cases(8), gen_pair(gen_usize(100, 900), gen_usize(1, 20)), |&(n, k)| {
        {
            let mut cache = build_cache.borrow_mut();
            let rebuild = !matches!(&*cache, Some((cn, _)) if *cn == n);
            if rebuild {
                let docs = rand_docs(n, 128, 8, n as u64);
                let fp: Vec<f32> = docs.iter().map(|&v| v as f32 / 128.0).collect();
                let db = quantize(&fp, n, 128, QuantScheme::Int8);
                let cfg = ChipConfig {
                    cores: 4,
                    map_points: 25,
                    ..ChipConfig::paper_default(128, Metric::Mips)
                };
                *cache = Some((n, DircChip::build(cfg, &db)));
            }
        }
        let cache = build_cache.borrow();
        let chip = &cache.as_ref().unwrap().1;
        let mut rng = Pcg::new(k as u64);
        let q: Vec<i8> = (0..128).map(|_| rng.int_in(-128, 127) as i8).collect();
        let plan = QueryPlan::topk(k).stream(&mut rng).build().unwrap();
        let out = chip.execute(&q, &plan);
        let (top, stats) = (out.topk, out.stats);
        if top.len() != k.min(n) {
            return false;
        }
        let mut ids: Vec<u64> = top.iter().map(|d| d.doc_id).collect();
        if !ids.iter().all(|&i| (i as usize) < n) {
            return false;
        }
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != top.len() {
            return false;
        }
        if !top.windows(2).all(|w| w[0].score >= w[1].score) {
            return false;
        }
        stats.docs_scored as usize == n
    });
}

/// Quantisation bounds for arbitrary scale data.
#[test]
fn prop_quantisation_in_range_any_scale() {
    forall(cases(40), gen_pair(gen_usize(1, 64), gen_usize(0, 12)), |&(n, mag)| {
        let dim = 32;
        let scale = 10f32.powi(mag as i32 - 6);
        let mut rng = Pcg::new((n + mag) as u64);
        let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32 * scale).collect();
        for scheme in [QuantScheme::Int8, QuantScheme::Int4] {
            let q = quantize(&x, n, dim, scheme);
            if !q
                .values
                .iter()
                .all(|&v| (v as i32) >= scheme.qmin() && (v as i32) <= scheme.qmax())
            {
                return false;
            }
        }
        true
    });
}

/// Write path: `program_cell` always terminates within the model's pulse
/// budget, lands the device on the requested MLC level, and its measured
/// time/energy are exactly the per-pulse costs times the pulses issued —
/// for arbitrary target levels and per-pulse yields.
#[test]
fn prop_program_cell_bounded_and_hits_target() {
    forall(
        cases(60),
        gen_pair(gen_usize(0, 3), gen_usize(5, 95)),
        |&(level_idx, yield_pct)| {
            let wm = WriteModel {
                pulse_yield: yield_pct as f64 / 100.0,
                ..WriteModel::default()
            };
            let level = MlcLevel::from_index(level_idx);
            let mut rng = Pcg::new((level_idx * 100 + yield_pct) as u64);
            for _ in 0..25 {
                let w = wm.program_cell(level, &mut rng);
                if w.pulses < 1 || w.pulses > wm.max_pulses {
                    return false;
                }
                if w.device.level != level {
                    return false;
                }
                let want_t = w.pulses as f64 * (wm.pulse_s + wm.verify_s);
                let want_e = w.pulses as f64 * (wm.pulse_j + wm.verify_j);
                if (w.time_s - want_t).abs() > 1e-15 || (w.energy_j - want_e).abs() > 1e-18 {
                    return false;
                }
            }
            true
        },
    );
}

/// The native/fallback breakeven is monotone in the update rate: the
/// larger the fraction of the database each update rewrites, the more
/// queries the write must amortise over before native NVM mode wins.
#[test]
fn prop_breakeven_monotone_in_update_rate() {
    forall(
        cases(40),
        gen_pair(gen_usize(1, 8), gen_usize(1, 16)),
        |&(mb, macros)| {
            let f = SramFallbackModel::default();
            let w = WriteModel::default();
            let db_bytes = mb << 20;
            let mut prev = 0.0f64;
            for pct in [1usize, 5, 10, 25, 50, 75, 100] {
                let be = f.breakeven_queries_at_rate(&w, db_bytes, macros, pct as f64 / 100.0);
                if be < prev - 1e-12 || !be.is_finite() || be < 0.0 {
                    return false;
                }
                prev = be;
            }
            // Full-rate form agrees with the original breakeven.
            let full = f.breakeven_queries_at_rate(&w, db_bytes, macros, 1.0);
            (full - f.breakeven_queries(&w, db_bytes, macros)).abs() < 1e-12
        },
    );
}

/// `MutationStats` accounting: applying a batch equals applying its
/// documents one at a time over the same rng stream — per-core costs,
/// totals, and the total() == sum(per_core) identity all agree exactly.
#[test]
fn prop_update_cost_totals_equal_per_macro_sum() {
    // One base chip, cloned per case (cheap: cores are shared via Arc
    // until a write touches them).
    let map_docs = rand_docs(64, 128, 8, 77);
    let fp: Vec<f32> = map_docs.iter().map(|&v| v as f32 / 128.0).collect();
    let db = quantize(&fp, 64, 128, QuantScheme::Int8);
    let base = DircChip::build(
        ChipConfig {
            cores: 2,
            map_points: 25,
            ..ChipConfig::paper_default(128, Metric::Mips)
        },
        &db,
    );
    forall(cases(10), gen_pair(gen_usize(1, 5), gen_usize(0, 1000)), |&(n_upd, seed)| {
        let updates: Vec<(u64, DocPayload)> = (0..n_upd)
            .map(|i| {
                let id = ((seed + i * 13) % 64) as u64;
                (id, DocPayload { values: db.row(id as usize).to_vec(), norm: db.norms[id as usize] })
            })
            .collect();

        let mut chip_batch = base.clone();
        let mut chip_single = base.clone();
        let mut r1 = Pcg::new(seed as u64);
        let mut r2 = Pcg::new(seed as u64);

        let batch = chip_batch.update_docs(&updates, &mut r1).unwrap();
        let mut folded = dirc_rag::dirc::chip::MutationStats::default();
        for u in &updates {
            let s = chip_single.update_docs(std::slice::from_ref(u), &mut r2).unwrap();
            folded.merge(&s);
        }

        // Batch == singles over the same rng stream.
        if batch.write_pulses != folded.write_pulses
            || batch.write_cycles != folded.write_cycles
            || batch.docs_updated != folded.docs_updated
        {
            return false;
        }
        if batch.per_core.len() != folded.per_core.len() {
            return false;
        }
        for (a, b) in batch.per_core.iter().zip(&folded.per_core) {
            if a.cells_written != b.cells_written
                || (a.energy_j - b.energy_j).abs() > 1e-18
                || (a.time_s - b.time_s).abs() > 1e-15
            {
                return false;
            }
        }
        // total() is exactly the per-core sum.
        let t = batch.total();
        let sum_cells: usize = batch.per_core.iter().map(|c| c.cells_written).sum();
        let sum_e: f64 = batch.per_core.iter().map(|c| c.energy_j).sum();
        let sum_t: f64 = batch.per_core.iter().map(|c| c.time_s).sum();
        t.cells_written == sum_cells
            && (t.energy_j - sum_e).abs() < 1e-18
            && (t.time_s - sum_t).abs() < 1e-15
    });
}

// ---------------------------------------------------------------------
// Two-stage cluster-pruned retrieval properties.

/// One shared clustered chip for the read-only pruning properties
/// (building is the expensive part; queries are cheap).
fn clustered_chip(n: usize, cores: usize, n_clusters: usize) -> DircChip {
    let docs = rand_docs(n, 128, 8, 0xC1);
    let fp: Vec<f32> = docs.iter().map(|&v| v as f32 / 128.0).collect();
    let db = quantize(&fp, n, 128, QuantScheme::Int8);
    let cfg = ChipConfig {
        cores,
        map_points: 25,
        cluster: ClusterPolicy { n_clusters, nprobe: 2, kmeans_iters: 6 },
        ..ChipConfig::paper_default(128, Metric::Mips)
    };
    DircChip::build(cfg, &db)
}

/// Doc ids resident on the cores a mask selects (live slots only).
fn probed_ids(chip: &DircChip, mask: &[bool]) -> std::collections::HashSet<u64> {
    chip.cores()
        .iter()
        .enumerate()
        .filter(|(c, _)| mask[*c])
        .flat_map(|(_, core)| {
            core.doc_ids()
                .iter()
                .zip(core.live())
                .filter(|(_, &l)| l)
                .map(|(&id, _)| id)
        })
        .collect()
}

/// Pruned retrieval is *exactly* exhaustive retrieval restricted to the
/// probed macros: for random (nprobe, k, query seed), the pruned top-k
/// equals the full noisy ranking filtered to the probed doc set and
/// truncated — same ids, same score bits. (In particular every pruned
/// result appears in the exhaustive ranking: subset by construction.)
#[test]
fn prop_pruned_equals_exhaustive_restricted_to_probed() {
    let chip = clustered_chip(480, 4, 8);
    let n = chip.n_docs();
    forall(
        cases(25),
        gen_pair(gen_usize(1, 7), gen_pair(gen_usize(1, 12), gen_usize(0, 1000))),
        |&(nprobe, (k, seed))| {
            let mut qrng = Pcg::new(seed as u64);
            let q: Vec<i8> = (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect();
            // Same plan seed -> same query nonce -> identical flips in
            // both runs; only the candidate set differs.
            let pruned = chip
                .execute(
                    &q,
                    &QueryPlan::topk(k)
                        .prune(Prune::Probe(nprobe))
                        .seed(seed as u64 + 5000)
                        .build()
                        .unwrap(),
                )
                .topk;
            let full = chip
                .execute(
                    &q,
                    &QueryPlan::topk(n)
                        .prune(Prune::None)
                        .seed(seed as u64 + 5000)
                        .build()
                        .unwrap(),
                )
                .topk;
            let Some(mask) = chip.macro_mask(&q, Prune::Probe(nprobe)) else {
                // Degenerate mask -> pruned ran exhaustively.
                return pruned == full[..k.min(full.len())];
            };
            let probed = probed_ids(&chip, &mask);
            let want: Vec<_> = full
                .iter()
                .filter(|d| probed.contains(&d.doc_id))
                .take(k)
                .cloned()
                .collect();
            pruned == want
        },
    );
}

/// Recall@k against the exhaustive run is monotone non-decreasing in
/// `nprobe`, and `nprobe = n_clusters` recovers the exhaustive results
/// bit-for-bit (ids, score bits, and the full hardware census).
#[test]
fn prop_recall_monotone_in_nprobe_and_full_probe_exact() {
    let chip = clustered_chip(480, 4, 8);
    forall(cases(12), gen_pair(gen_usize(1, 10), gen_usize(0, 500)), |&(k, seed)| {
        let mut qrng = Pcg::new(seed as u64 + 900);
        let q: Vec<i8> = (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect();
        let run = |prune: Prune| {
            let out = chip.execute(
                &q,
                &QueryPlan::topk(k).prune(prune).seed(seed as u64).build().unwrap(),
            );
            (out.topk, out.stats)
        };
        let (full, full_stats) = run(Prune::None);
        let full_ids: std::collections::HashSet<u64> =
            full.iter().map(|d| d.doc_id).collect();
        // Monotonicity rides on the probed sets being prefix-nested,
        // which a degenerate all-empty-probes mask (falls back to
        // exhaustive) would break spuriously — only assert it while the
        // masks are real. (k-means on this fixture should never produce
        // an empty top cluster, but the property must not hinge on it.)
        let mut prev_recall = 0usize;
        let mut masks_real = true;
        for nprobe in 1..=8usize {
            let (pruned, stats) = run(Prune::Probe(nprobe));
            let recall =
                pruned.iter().filter(|d| full_ids.contains(&d.doc_id)).count();
            if nprobe < 8 && chip.macro_mask(&q, Prune::Probe(nprobe)).is_none() {
                masks_real = false;
            }
            if masks_real && recall < prev_recall {
                return false;
            }
            prev_recall = recall;
            if stats.macros_sensed + stats.macros_skipped != 4 {
                return false;
            }
            if nprobe == 8 {
                // Full probe: bit-identical to exhaustive.
                if pruned != full
                    || stats.cycles != full_stats.cycles
                    || stats.work_cycles != full_stats.work_cycles
                    || stats.energy_j.to_bits() != full_stats.energy_j.to_bits()
                    || stats.macros_skipped != 0
                {
                    return false;
                }
            }
        }
        prev_recall == full.len()
    });
}

/// Cluster assignment is a partition of the live corpus — every live
/// slot carries exactly one in-range cluster, hosted-cluster bitsets
/// match a from-scratch recomputation, global ids stay unique — and the
/// partition survives random add/update/delete bursts.
#[test]
fn prop_cluster_partition_survives_churn() {
    let check = |chip: &DircChip| -> bool {
        let Some(index) = chip.cluster_index() else { return false };
        let k = index.n_clusters();
        let mut live_total = 0usize;
        let mut ids = std::collections::HashSet::new();
        for (c, core) in chip.cores().iter().enumerate() {
            let clusters = core.slot_clusters();
            if clusters.len() != core.doc_ids().len() {
                return false;
            }
            let mut hosted = vec![false; k];
            for ((&cl, &l), &id) in
                clusters.iter().zip(core.live()).zip(core.doc_ids())
            {
                if cl as usize >= k {
                    return false;
                }
                if l {
                    live_total += 1;
                    hosted[cl as usize] = true;
                    if !ids.insert(id) {
                        return false; // a live doc placed twice
                    }
                }
            }
            // Bitset == recomputation, in both directions.
            for (cl, &h) in hosted.iter().enumerate() {
                if index.core_has(c, cl as u32) != h {
                    return false;
                }
            }
        }
        live_total == chip.n_docs()
    };
    forall(cases(8), gen_pair(gen_usize(0, 1000), gen_usize(1, 12)), |&(seed, burst)| {
        let mut chip = clustered_chip(200, 4, 8);
        if !check(&chip) {
            return false;
        }
        let mut rng = Pcg::new(seed as u64);
        let mut wrng = Pcg::new(seed as u64 + 1);
        for _ in 0..3 {
            // Adds: random payloads (saturating the grid is fine).
            let adds: Vec<DocPayload> = (0..burst)
                .map(|_| {
                    DocPayload::from_values(
                        (0..128).map(|_| rng.int_in(-128, 127) as i8).collect(),
                    )
                })
                .collect();
            let (new_ids, _) = chip.add_docs(&adds, &mut wrng).expect("add burst");
            // Updates: rewrite random resident docs with fresh payloads
            // (their cluster may legitimately move).
            let updates: Vec<(u64, DocPayload)> = (0..burst)
                .map(|_| {
                    let id = rng.index(200) as u64;
                    (
                        id,
                        DocPayload::from_values(
                            (0..128).map(|_| rng.int_in(-128, 127) as i8).collect(),
                        ),
                    )
                })
                .collect();
            chip.update_docs(&updates, &mut wrng).expect("update burst");
            // Deletes: some of the docs just added, plus a maybe-missing id.
            let mut dels: Vec<u64> = new_ids.iter().step_by(2).copied().collect();
            dels.push(9_999_999);
            chip.delete_docs(&dels);
            if !check(&chip) {
                return false;
            }
        }
        true
    });
}

/// Remap bijection for arbitrary random seeds and both precisions
/// (system-level re-statement of the module-level property).
#[test]
fn prop_remap_bijective_all_strategies() {
    let map = VariationModel::default().extract_error_map(30, 5);
    forall(cases(30), gen_pair(gen_usize(0, 1_000_000), gen_usize(0, 1)), |&(seed, b)| {
        let bits = if b == 0 { 4 } else { 8 };
        for strat in [
            RemapStrategy::Interleaved,
            RemapStrategy::Random { seed: seed as u64 },
            RemapStrategy::ErrorAware,
        ] {
            let l = Layout::build(bits, strat, &map);
            let mut seen = std::collections::HashSet::new();
            for w in 0..l.words {
                for bit in 0..l.bits {
                    let s = l.slot(w, bit);
                    if !seen.insert((s.pos, s.msb)) || l.word_bit(s) != (w, bit) {
                        return false;
                    }
                }
            }
            if seen.len() != SLOTS_PER_CELL {
                return false;
            }
        }
        true
    });
}

// ---------------------------------------------------------------------
// Multi-chip fleet properties (fleet::DircFleet).

/// Shard-count invariance: for random (k, nprobe, query), the fleet's
/// top-k ids *and score bits* are identical at 1, 2, and 4 shards and
/// equal to the bare union chip's.
#[test]
fn prop_fleet_shard_count_invariance() {
    let chip = clustered_chip(480, 8, 16);
    let db_docs = rand_docs(480, 128, 8, 0xC1);
    let fp: Vec<f32> = db_docs.iter().map(|&v| v as f32 / 128.0).collect();
    let db = quantize(&fp, 480, 128, QuantScheme::Int8);
    let cfg = ChipConfig {
        cores: 8,
        map_points: 25,
        cluster: ClusterPolicy { n_clusters: 16, nprobe: 2, kmeans_iters: 6 },
        ..ChipConfig::paper_default(128, Metric::Mips)
    };
    let fleets: Vec<dirc_rag::fleet::DircFleet> = [1usize, 2, 4]
        .iter()
        .map(|&s| dirc_rag::fleet::DircFleet::build(cfg.clone(), &db, s))
        .collect();
    forall(
        cases(20),
        gen_pair(gen_usize(1, 10), gen_pair(gen_usize(1, 16), gen_usize(0, 1000))),
        |&(k, (nprobe, seed))| {
            let mut qrng = Pcg::new(seed as u64 + 40);
            let q: Vec<i8> = (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect();
            let prune = match seed % 3 {
                0 => Prune::None,
                1 => Prune::Default,
                _ => Prune::Probe(nprobe),
            };
            let plan =
                QueryPlan::topk(k).prune(prune).seed(seed as u64 + 11).build().unwrap();
            let want = chip.execute(&q, &plan).topk;
            fleets.iter().all(|fleet| {
                let got = fleet.execute(&q, &plan).topk;
                got.len() == want.len()
                    && got.iter().zip(&want).all(|(a, b)| {
                        a.doc_id == b.doc_id && a.score.to_bits() == b.score.to_bits()
                    })
            })
        },
    );
}

/// The fleet's cluster partition and id directory survive fleet-routed
/// add/update/delete bursts: every live slot on every shard carries an
/// in-range cluster, hosted-cluster bitsets match recomputation, global
/// ids stay unique fleet-wide, the id directory points at the resident
/// shard, and fresh ids respect the per-shard id lanes.
#[test]
fn prop_fleet_partition_survives_routed_churn() {
    let base_n = 200u64;
    let fleet_ok = |fleet: &dirc_rag::fleet::DircFleet| -> bool {
        let stride = fleet.n_chips() as u64;
        let mut ids = std::collections::HashSet::new();
        let mut live_total = 0usize;
        for (s, shard) in fleet.shards().iter().enumerate() {
            let Some(index) = shard.cluster_index() else { return false };
            let k = index.n_clusters();
            for (c, core) in shard.cores().iter().enumerate() {
                let clusters = core.slot_clusters();
                if clusters.len() != core.doc_ids().len() {
                    return false;
                }
                let mut hosted = vec![false; k];
                for ((&cl, &l), &id) in
                    clusters.iter().zip(core.live()).zip(core.doc_ids())
                {
                    if cl as usize >= k {
                        return false;
                    }
                    if l {
                        live_total += 1;
                        hosted[cl as usize] = true;
                        if !ids.insert(id) {
                            return false; // a live doc placed twice fleet-wide
                        }
                        if fleet.shard_of(id) != Some(s) {
                            return false; // directory points at the wrong shard
                        }
                        // Fresh ids come out of shard s's lane.
                        if id >= base_n && (id - base_n) % stride != s as u64 {
                            return false;
                        }
                    }
                }
                for (cl, &h) in hosted.iter().enumerate() {
                    if index.core_has(c, cl as u32) != h {
                        return false;
                    }
                }
            }
        }
        live_total == fleet.n_docs()
    };
    forall(cases(6), gen_pair(gen_usize(0, 1000), gen_usize(1, 10)), |&(seed, burst)| {
        let docs = rand_docs(base_n as usize, 128, 8, 0xF2);
        let fp: Vec<f32> = docs.iter().map(|&v| v as f32 / 128.0).collect();
        let db = quantize(&fp, base_n as usize, 128, QuantScheme::Int8);
        let cfg = ChipConfig {
            cores: 4,
            map_points: 25,
            cluster: ClusterPolicy { n_clusters: 8, nprobe: 2, kmeans_iters: 6 },
            ..ChipConfig::paper_default(128, Metric::Mips)
        };
        let n_chips = if seed % 2 == 0 { 2 } else { 4 };
        let mut fleet = dirc_rag::fleet::DircFleet::build(cfg, &db, n_chips);
        if !fleet_ok(&fleet) {
            return false;
        }
        let mut rng = Pcg::new(seed as u64);
        let mut wrng = Pcg::new(seed as u64 + 1);
        for _ in 0..3 {
            let adds: Vec<DocPayload> = (0..burst)
                .map(|_| {
                    DocPayload::from_values(
                        (0..128).map(|_| rng.int_in(-128, 127) as i8).collect(),
                    )
                })
                .collect();
            let (new_ids, st) = fleet.add_docs(&adds, &mut wrng).expect("add burst");
            if st.docs_added != burst || new_ids.len() != burst {
                return false;
            }
            let updates: Vec<(u64, DocPayload)> = (0..burst)
                .map(|_| {
                    let id = rng.index(base_n as usize) as u64;
                    (
                        id,
                        DocPayload::from_values(
                            (0..128).map(|_| rng.int_in(-128, 127) as i8).collect(),
                        ),
                    )
                })
                .collect();
            fleet.update_docs(&updates, &mut wrng).expect("update burst");
            let mut dels: Vec<u64> = new_ids.iter().step_by(2).copied().collect();
            dels.push(9_999_999); // never-resident id: counts missing only
            let st = fleet.delete_docs(&dels);
            if st.missing_ids != 1 {
                return false;
            }
            if !fleet_ok(&fleet) {
                return false;
            }
        }
        true
    });
}

/// Pruned fleet retrieval is exactly exhaustive fleet retrieval
/// restricted to the probed shards' probed macros: the fleet-level
/// mirror of `prop_pruned_equals_exhaustive_restricted_to_probed`, with
/// the candidate set unioned across exactly the shards the route
/// targets.
#[test]
fn prop_fleet_pruned_equals_exhaustive_restricted_to_probed_shards() {
    let docs = rand_docs(480, 128, 8, 0xC1);
    let fp: Vec<f32> = docs.iter().map(|&v| v as f32 / 128.0).collect();
    let db = quantize(&fp, 480, 128, QuantScheme::Int8);
    let cfg = ChipConfig {
        cores: 8,
        map_points: 25,
        cluster: ClusterPolicy { n_clusters: 16, nprobe: 2, kmeans_iters: 6 },
        ..ChipConfig::paper_default(128, Metric::Mips)
    };
    let fleet = dirc_rag::fleet::DircFleet::build(cfg, &db, 4);
    let n = fleet.n_docs();
    forall(
        cases(18),
        gen_pair(gen_usize(1, 15), gen_pair(gen_usize(1, 12), gen_usize(0, 1000))),
        |&(nprobe, (k, seed))| {
            let mut qrng = Pcg::new(seed as u64 + 70);
            let q: Vec<i8> = (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect();
            let s = seed as u64 + 6000;
            let pruned = fleet
                .execute(
                    &q,
                    &QueryPlan::topk(k)
                        .prune(Prune::Probe(nprobe))
                        .seed(s)
                        .build()
                        .unwrap(),
                )
                .topk;
            let full = fleet
                .execute(&q, &QueryPlan::topk(n).prune(Prune::None).seed(s).build().unwrap())
                .topk;
            let route = fleet.route(&q, k, Prune::Probe(nprobe));
            if route.sub_prune == Prune::None {
                // Degenerate route -> the pruned plan ran exhaustively.
                return pruned == full[..k.min(full.len())];
            }
            // Candidate set: on each targeted shard, the docs its own
            // macro mask probes (a shard falling back to exhaustive
            // contributes all its live docs).
            let mut probed = std::collections::HashSet::new();
            for (s, shard) in fleet.shards().iter().enumerate() {
                if !route.targets[s] {
                    continue;
                }
                match shard.macro_mask(&q, route.sub_prune) {
                    Some(mask) => probed.extend(probed_ids(shard, &mask)),
                    None => probed.extend(probed_ids(
                        shard,
                        &vec![true; shard.cores().len()],
                    )),
                }
            }
            let want: Vec<_> = full
                .iter()
                .filter(|d| probed.contains(&d.doc_id))
                .take(k)
                .cloned()
                .collect();
            pruned == want
        },
    );
}

/// Structural property of adaptive early termination: `Prune::Adaptive`
/// never invents a candidate set — it only picks WHERE to stop along the
/// centroid ranking. With a zero margin the stop is disarmed and the
/// policy must be bit-identical to `Prune::Probe(max_probe)`; armed,
/// whatever stopping point `p` the controller reports
/// (`stats.clusters_probed`) must reproduce `Prune::Probe(p)` exactly —
/// top-k, cycle census, and energy to the bit (exhaustive fallbacks
/// report 0 and must match `Prune::None`).
#[test]
fn prop_adaptive_is_a_probe_plan_at_its_stopping_point() {
    let docs = rand_docs(360, 128, 8, 91);
    let fp: Vec<f32> = docs.iter().map(|&v| v as f32 / 128.0).collect();
    let db = quantize(&fp, 360, 128, QuantScheme::Int8);
    let chip = DircChip::build(
        ChipConfig {
            cores: 6,
            map_points: 25,
            cluster: ClusterPolicy { n_clusters: 6, nprobe: 2, kmeans_iters: 4 },
            ..ChipConfig::paper_default(128, Metric::Mips)
        },
        &db,
    );
    forall(cases(16), gen_pair(gen_usize(1, 8), gen_usize(0, 500)), |&(k, seed)| {
        let mut rng = Pcg::new(seed as u64 + 3);
        let q: Vec<i8> = (0..128).map(|_| rng.int_in(-128, 127) as i8).collect();
        let margin = (seed % 5) as f64 * 0.02; // 0.0 .. 0.08, disarmed case included
        let cap = 1 + (seed / 5) % 6; // 1 .. 6 == n_clusters
        let s = seed as u64 + 7;
        let plan = |prune: Prune| QueryPlan::topk(k).prune(prune).seed(s).build().unwrap();
        let a = chip.execute(&q, &plan(Prune::adaptive(margin, cap)));
        let reference = if margin == 0.0 {
            // Disarmed: the pinned degradation invariant.
            Prune::Probe(cap)
        } else {
            match a.stats.clusters_probed as usize {
                0 => Prune::None, // exhaustive fallback
                p => Prune::Probe(p),
            }
        };
        let r = chip.execute(&q, &plan(reference));
        a.topk == r.topk
            && a.stats.sense == r.stats.sense
            && a.stats.cycles == r.stats.cycles
            && a.stats.macros_sensed == r.stats.macros_sensed
            && a.stats.macros_skipped == r.stats.macros_skipped
            && a.stats.energy_j.to_bits() == r.stats.energy_j.to_bits()
    });
}

// ---------------------------------------------------------------------
// Load-harness digest invariance (the serving-side determinism
// contract that the static-analysis pass machine-checks the inputs of).

/// Trace generation and the queueing model are pure functions of their
/// inputs, and the chip's modeled per-query service times are identical
/// whether the plan executes serially or on worker pools of different
/// widths — so `Trace::digest` and `LoadReport::digest` are invariant
/// across repeat runs AND across thread counts, and only a seed change
/// moves them.
#[test]
fn prop_load_digests_invariant_across_threads_and_repeats() {
    use dirc_rag::util::pool::ThreadPool;
    use dirc_rag::workload::{queueing, QueueModelConfig, Trace, TraceConfig};
    use std::sync::Arc;

    let chip = clustered_chip(256, 4, 8);
    let distinct = 16usize;
    forall(cases(6), gen_usize(0, 1000), |&seed| {
        let tcfg = TraceConfig {
            n_queries: 400,
            distinct_queries: distinct,
            n_docs: 256,
            tenant_mix: vec![0.7, 0.3],
            mutate_every: 120,
            target_qps: 80_000.0,
            seed: seed as u64,
            ..TraceConfig::default()
        };
        // Trace digest: repeat-identical, seed-sensitive.
        let trace = Trace::generate(&tcfg);
        if trace.digest() != Trace::generate(&tcfg).digest() {
            return false;
        }
        if Trace::generate(&TraceConfig { seed: seed as u64 + 9001, ..tcfg.clone() })
            .digest()
            == trace.digest()
        {
            return false;
        }

        // Per-distinct-query service times through the chip, serial vs
        // pooled: the crate's serial==pooled contract says the bits match.
        let mut qrng = Pcg::new(seed as u64 + 1);
        let queries: Vec<Vec<i8>> = (0..distinct)
            .map(|_| (0..128).map(|_| qrng.int_in(-128, 127) as i8).collect())
            .collect();
        let service_for = |plan: &QueryPlan| -> Vec<f64> {
            chip.execute_batch(&queries, plan)
                .iter()
                .map(|o| o.stats.latency_s)
                .collect()
        };
        let base = QueryPlan::topk(5).seed(seed as u64 + 2);
        let serial = service_for(&base.clone().serial().build().unwrap());
        let qcfg = QueueModelConfig {
            workers: 2,
            weights: vec![2, 1],
            tenant_names: vec!["gold".into(), "light".into()],
            ..QueueModelConfig::default()
        };
        let report = queueing::simulate(&trace, &serial, &qcfg);
        // Repeat run of the whole model: identical report bits.
        if queueing::simulate(&trace, &serial, &qcfg).digest() != report.digest() {
            return false;
        }
        for threads in [2usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let pooled = service_for(&base.clone().pool(pool).build().unwrap());
            if serial.len() != pooled.len()
                || serial
                    .iter()
                    .zip(&pooled)
                    .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return false;
            }
            // Same service bits -> same LoadReport digest regardless of
            // how wide the pool that produced them was.
            if queueing::simulate(&trace, &pooled, &qcfg).digest() != report.digest() {
                return false;
            }
        }
        true
    });
}
