//! Deterministic-RNG regression tests: pin the split-stream derivations
//! the parallel sharded query path depends on, so per-core seeding can
//! never silently change ranking results between PRs. The golden values
//! were computed independently from the PCG-XSH-RR 64/32 + SplitMix64
//! definitions.

use dirc_rag::dirc::chip::DircChip;
use dirc_rag::util::rng::Pcg;

#[test]
fn base_streams_pinned() {
    let mut r = Pcg::new(0);
    assert_eq!(
        [r.next_u32(), r.next_u32(), r.next_u32(), r.next_u32(), r.next_u32(), r.next_u32()],
        [0x8a5d_ea50, 0x8b65_b731, 0xa3f9_6e62, 0xc354_6b80, 0xc1c9_a143, 0x0bf1_2f6b]
    );
    let mut r = Pcg::new(42);
    assert_eq!(
        [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
        [
            0xffb9_6e1c_a3fa_3404,
            0xd934_78f7_bdfc_1488,
            0x272e_038b_e316_9985,
            0xc3aa_643d_bf3d_e067,
        ]
    );
    // The chip's default build seed.
    let mut r = Pcg::new(0xD12C_0001);
    assert_eq!([r.next_u32(), r.next_u32()], [0x34a8_a3b4, 0x6c93_d7fd]);
}

#[test]
fn split_streams_pinned() {
    let root = Pcg::new(7);
    let mut f = root.split(0);
    assert_eq!(
        [f.next_u32(), f.next_u32(), f.next_u32(), f.next_u32()],
        [0x1e34_b72e, 0xc369_ba32, 0x5d89_7d83, 0xa9fd_1eae]
    );
    let mut f = root.split(1);
    assert_eq!(
        [f.next_u32(), f.next_u32(), f.next_u32(), f.next_u32()],
        [0xdc91_4696, 0x18d0_d2b8, 0x5b13_9992, 0xc29b_bad4]
    );
    let mut f = root.split(0xDEAD_BEEF);
    assert_eq!([f.next_u32(), f.next_u32()], [0xf5fc_d08d, 0x43aa_f370]);
    // Splitting must not advance the parent.
    let mut a = root.clone();
    let mut b = Pcg::new(7);
    for _ in 0..8 {
        assert_eq!(a.next_u64(), b.next_u64());
    }
}

#[test]
fn keyed_per_core_streams_pinned() {
    let nonce = 0x0123_4567_89AB_CDEF;
    let want: [[u32; 4]; 4] = [
        [0x5641_5adc, 0xbc31_383a, 0x46c7_5a69, 0x048d_67c2],
        [0x8b0a_9b5f, 0x4ad4_5190, 0x117b_92e3, 0xd029_a4bc],
        [0x5fe3_8620, 0x6aca_a1ef, 0x814a_8bba, 0x0303_8aa5],
        [0xa771_b852, 0x8ee4_a590, 0x2de7_169e, 0xee31_043b],
    ];
    for (lane, w) in want.iter().enumerate() {
        let mut k = Pcg::keyed(nonce, lane as u64);
        assert_eq!(
            [k.next_u32(), k.next_u32(), k.next_u32(), k.next_u32()],
            *w,
            "lane {lane}"
        );
    }
}

#[test]
fn chip_core_stream_is_keyed_stream() {
    // The chip's per-(query, core) sensing stream must be exactly
    // Pcg::keyed(qnonce, core) — the documented determinism contract.
    for nonce in [0u64, 1, 0x0123_4567_89AB_CDEF, u64::MAX] {
        for core in 0..16usize {
            let mut a = DircChip::core_stream(nonce, core);
            let mut b = Pcg::keyed(nonce, core as u64);
            for _ in 0..8 {
                assert_eq!(a.next_u64(), b.next_u64(), "nonce {nonce:#x} core {core}");
            }
        }
    }
}

#[test]
fn per_core_streams_mutually_independent() {
    // Adjacent lanes must not be correlated: over 64 draws, collisions
    // between any two of the 16 core streams should be absent (chance of
    // a single u32 collision across all pairs and draws is ~2e-6).
    let nonce = 0xFEED_F00D_u64;
    let streams: Vec<Vec<u32>> = (0..16)
        .map(|c| {
            let mut r = Pcg::keyed(nonce, c);
            (0..64).map(|_| r.next_u32()).collect()
        })
        .collect();
    for a in 0..16 {
        for b in (a + 1)..16 {
            let same = streams[a]
                .iter()
                .zip(&streams[b])
                .filter(|(x, y)| x == y)
                .count();
            assert_eq!(same, 0, "lanes {a} and {b} collide");
        }
    }
}
