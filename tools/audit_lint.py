#!/usr/bin/env python3
"""Offline mirror of `cargo run -p dirc-lint` (rust/lint).

The build container for this repo has no Rust toolchain, so this script
re-implements the dirc-lint rules 1:1 (masking lexer, #[cfg(test)]
skipping, the five rules, allowlist + stale detection) to audit
`rust/src` without cargo. CI runs the real binary; this is the local
cross-check. Keep the two in sync — rule drift here is a bug.

Usage: python3 tools/audit_lint.py [--src rust/src] [--allowlist rust/lint/allowlist.txt]
Exit codes match dirc-lint: 0 clean, 1 violations, 2 stale/usage.
"""

import argparse
import sys
from pathlib import Path

RULES = (
    "hash-collections",
    "naked-rng",
    "wall-clock",
    "undocumented-unsafe",
    "undocumented-ordering",
)
DETERMINISTIC_PREFIXES = (
    "baseline/", "data/", "dirc/", "eval/", "fleet/", "retrieval/", "sim/",
    "workload/",
)
WALLCLOCK_EXEMPT = ("workload/runner.rs",)
RNG_OWNERS = ("retrieval/plan.rs", "util/prop.rs", "util/rng.rs")
COMMENT_WALK_LIMIT = 40


def mask_source(src):
    """Return (code_lines, comment_lines): comments/strings blanked to
    spaces in code, comment text collected per line."""
    n = len(src)
    code = []
    comments = [[]]
    i = 0

    def blank(ch, comment):
        if ch == "\n":
            code.append("\n")
            comments.append([])
        else:
            if comment:
                comments[-1].append(ch)
            code.append(" ")

    while i < n:
        c = src[i]
        if c == "\n":
            code.append("\n")
            comments.append([])
            i += 1
            continue
        if c == "/" and src.startswith("//", i):
            while i < n and src[i] != "\n":
                blank(src[i], True)
                i += 1
            continue
        if c == "/" and src.startswith("/*", i):
            depth = 0
            while i < n:
                if src.startswith("/*", i):
                    depth += 1
                    blank("/", True)
                    blank("*", True)
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    blank("*", True)
                    blank("/", True)
                    i += 2
                    if depth == 0:
                        break
                else:
                    blank(src[i], True)
                    i += 1
            continue
        if c.isalnum() or c == "_":
            start = i
            while i < n and (src[i].isalnum() or src[i] == "_"):
                i += 1
            word = src[start:i]
            raw_capable = word in ("r", "br")
            is_prefix = word in ("r", "b", "br")
            starts_string = is_prefix and i < n and (
                src[i] == '"' or (raw_capable and src[i] == "#")
            )
            if not starts_string:
                code.extend(word)
                continue
            code.extend(" " * len(word))
            if raw_capable:
                hashes = 0
                while i < n and src[i] == "#":
                    hashes += 1
                    blank("#", False)
                    i += 1
                if i < n and src[i] == '"':
                    blank('"', False)
                    i += 1
                    closer = '"' + "#" * hashes
                    while i < n:
                        if src.startswith(closer, i):
                            for ch in closer:
                                blank(ch, False)
                            i += len(closer)
                            break
                        blank(src[i], False)
                        i += 1
                continue
            # b"...": mask inline (c still holds the prefix char, so the
            # '"' branch below would not see the opening quote).
            blank('"', False)
            i += 1
            while i < n:
                if src[i] == "\\" and i + 1 < n:
                    blank(src[i], False)
                    blank(src[i + 1], False)
                    i += 2
                    continue
                if src[i] == '"':
                    blank('"', False)
                    i += 1
                    break
                blank(src[i], False)
                i += 1
            continue
        if c == '"':
            blank('"', False)
            i += 1
            while i < n:
                if src[i] == "\\" and i + 1 < n:
                    blank(src[i], False)
                    blank(src[i + 1], False)
                    i += 2
                    continue
                if src[i] == '"':
                    blank('"', False)
                    i += 1
                    break
                blank(src[i], False)
                i += 1
            continue
        if c == "'":
            is_char = (i + 1 < n and src[i + 1] == "\\") or (
                i + 2 < n and src[i + 2] == "'" and src[i + 1] != "'"
            )
            if is_char:
                blank("'", False)
                i += 1
                while i < n:
                    if src[i] == "\\" and i + 1 < n:
                        blank(src[i], False)
                        blank(src[i + 1], False)
                        i += 2
                        continue
                    if src[i] == "'":
                        blank("'", False)
                        i += 1
                        break
                    blank(src[i], False)
                    i += 1
                continue
            code.append("'")
            i += 1
            continue
        code.append(c)
        i += 1

    lines = "".join(code).split("\n")
    comment_lines = ["".join(c) for c in comments]
    comment_lines += [""] * (len(lines) - len(comment_lines))
    return lines, comment_lines


def mark_test_regions(lines):
    in_test = [False] * len(lines)
    l = 0
    while l < len(lines):
        line = lines[l]
        col = line.find("#[cfg(test)]")
        if col < 0:
            col = line.find("#[cfg(all(test")
        if col < 0:
            l += 1
            continue
        depth = 0
        opened = False
        end = len(lines) - 1
        cur = l
        start_col = col
        done = False
        while cur < len(lines) and not done:
            for ci, ch in enumerate(lines[cur]):
                if cur == l and ci < start_col:
                    continue
                if ch == "{":
                    depth += 1
                    opened = True
                elif ch == "}":
                    if opened:
                        depth -= 1
                        if depth == 0:
                            end = cur
                            done = True
                            break
                elif ch == ";" and not opened:
                    end = cur
                    done = True
                    break
            cur += 1
            start_col = 0
        for k in range(l, end + 1):
            in_test[k] = True
        l = end + 1
    return in_test


def is_ident(ch):
    return ch.isalnum() or ch == "_" or ord(ch) >= 0x80


def find_word_from(line, word, start):
    at = start
    while at <= len(line):
        p = line.find(word, at)
        if p < 0:
            return -1
        before_ok = p == 0 or not is_ident(line[p - 1])
        end = p + len(word)
        after_ok = end >= len(line) or not is_ident(line[end])
        if before_ok and after_ok:
            return p
        at = p + max(len(word), 1)
    return -1


def has_word(line, word):
    return find_word_from(line, word, 0) >= 0


def has_pcg_new(line):
    frm = 0
    while True:
        p = find_word_from(line, "Pcg", frm)
        if p < 0:
            return False
        rest = line[p + 3 :].lstrip()
        if rest.startswith("::"):
            r2 = rest[2:].lstrip()
            if r2.startswith("new") and (
                len(r2) == 3 or not (r2[3].isalnum() or r2[3] == "_")
            ):
                return True
        frm = p + 3
    return False


def non_seqcst_ordering(line):
    for variant in ("Relaxed", "Acquire", "Release", "AcqRel"):
        frm = 0
        while True:
            p = find_word_from(line, "Ordering", frm)
            if p < 0:
                break
            rest = line[p + len("Ordering") :].lstrip()
            if rest.startswith("::") and rest[2:].lstrip().startswith(variant):
                return variant
            frm = p + len("Ordering")
    return None


def has_tag_comment(lines, comments, at, tag):
    if tag in comments[at]:
        return True
    k = at
    walked = 0
    while k > 0 and walked < COMMENT_WALK_LIMIT:
        k -= 1
        walked += 1
        if tag in comments[k]:
            return True
        code = lines[k].strip()
        if code and not (code.startswith("#[") or code.startswith("#!")):
            return False
    return False


def lint_source(rel, src):
    lines, comments = mask_source(src)
    orig = src.split("\n")
    in_test = mark_test_regions(lines)
    out = []
    deterministic = rel.startswith(DETERMINISTIC_PREFIXES)
    wallclock_scoped = deterministic and rel not in WALLCLOCK_EXEMPT
    rng_scoped = rel not in RNG_OWNERS

    def push(rule, l, msg):
        text = orig[l].strip() if l < len(orig) else ""
        out.append((rule, rel, l + 1, text, msg))

    for l, code in enumerate(lines):
        if in_test[l]:
            continue
        if deterministic:
            for coll in ("HashMap", "HashSet"):
                if has_word(code, coll):
                    push("hash-collections", l, f"{coll} in deterministic module")
        if rng_scoped and has_pcg_new(code):
            push("naked-rng", l, "naked Pcg::new outside stream owners")
        if wallclock_scoped:
            for clock in ("Instant", "SystemTime"):
                if has_word(code, clock):
                    push("wall-clock", l, f"{clock} in modeled path")
        if has_word(code, "unsafe") and not has_tag_comment(
            lines, comments, l, "SAFETY:"
        ):
            push("undocumented-unsafe", l, "unsafe without SAFETY: comment")
        variant = non_seqcst_ordering(code)
        if variant and not has_tag_comment(lines, comments, l, "ORDERING:"):
            push("undocumented-ordering", l, f"Ordering::{variant} without ORDERING: comment")
    return out


def parse_allowlist(text):
    entries = []
    for i, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = [p.strip() for p in line.split("|", 3)]
        if len(parts) != 4 or any(not p for p in parts):
            raise ValueError(f"allowlist line {i}: malformed: {line}")
        if parts[0] not in RULES:
            raise ValueError(f"allowlist line {i}: unknown rule {parts[0]}")
        entries.append((i, *parts))
    return entries


def main():
    ap = argparse.ArgumentParser()
    repo = Path(__file__).resolve().parent.parent
    ap.add_argument("--src", default=str(repo / "rust/src"))
    ap.add_argument("--allowlist", default=str(repo / "rust/lint/allowlist.txt"))
    args = ap.parse_args()
    src_root = Path(args.src)
    entries = parse_allowlist(Path(args.allowlist).read_text())

    files = sorted(src_root.rglob("*.rs"))
    sources = {}
    raw = []
    for path in files:
        rel = path.relative_to(src_root).as_posix()
        text = path.read_text()
        sources[rel] = text
        raw.extend(lint_source(rel, text))

    violations, suppressed = [], []
    for v in raw:
        rule, rel, _line, text, _msg = v
        if any(
            rule == e_rule and rel.endswith(e_path) and e_pat in text
            for (_i, e_rule, e_path, e_pat, _r) in entries
        ):
            suppressed.append(v)
        else:
            violations.append(v)
    stale = [
        e
        for e in entries
        if not any(
            rel.endswith(e[2]) and any(e[3] in l for l in text.split("\n"))
            for rel, text in sources.items()
        )
    ]

    print(f"audit_lint: {len(files)} files, {len(suppressed)} suppressed")
    for rule, rel, line, text, msg in violations:
        print(f"  {rel}:{line} [{rule}] {text}\n      {msg}")
    for e in stale:
        print(f"  stale allowlist entry line {e[0]}: {e[1]} | {e[2]} | {e[3]}")
    if stale:
        return 2
    if violations:
        return 1
    print("audit_lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
