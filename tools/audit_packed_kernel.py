#!/usr/bin/env python3
"""Independent recomputation of the packed bit-plane kernel identity.

No Rust toolchain ships in some build containers, so this script
re-derives the math that rust/src/retrieval/packed.rs relies on, in
plain Python, and checks it exhaustively enough to trust:

  1. bit-plane decomposition: for B-bit two's-complement values,
       dot(d, q) == sum_{db, qb} w(db) * w(qb) * popcount(D[db] & Q[qb])
     with w(b) = -2^(B-1) for the sign bit, else 2^b
     (mirrors bit_weight in rust/src/dirc/column.rs).
  2. i8 extreme headroom: |dot| at dim 512 with values in [-128, 127]
     fits i64 with enormous slack (the dot_i8 comment's claim).
  3. flip corrections: toggling stored bit `b` of element `e` in doc d
     changes dot(d, q) by exactly value_delta * q[e], where
     value_delta = -w(b) if the bit was 1 else +w(b)
     (mirrors Flip::value_delta in rust/src/dirc/macro_.rs).
  4. packing round-trip: the low B bits of the i8 two's-complement
     representation, interpreted through (1), reproduce the value.

Run: python3 tools/audit_packed_kernel.py   (exit 0 == all identities hold)
"""

import random

random.seed(0xD1AC)


def bit_weight(b, bits):
    return -(1 << b) if b == bits - 1 else (1 << b)


def low_bits(v, bits):
    # two's-complement truncation to `bits` bits (what the macro stores)
    return v & ((1 << bits) - 1)


def pack_planes(values, bits, dim):
    """Per-doc bit planes as Python ints (one int per plane == u64 words)."""
    planes = [0] * bits
    for e, v in enumerate(values):
        w = low_bits(v, bits)
        for b in range(bits):
            if (w >> b) & 1:
                planes[b] |= 1 << e
    assert dim >= len(values)
    return planes


def packed_dot(d_planes, q_planes, bits):
    acc = 0
    for db in range(bits):
        for qb in range(bits):
            acc += (
                bit_weight(db, bits)
                * bit_weight(qb, bits)
                * bin(d_planes[db] & q_planes[qb]).count("1")
            )
    return acc


def check_identity(bits, lo, hi, dims, trials):
    for dim in dims:
        for _ in range(trials):
            d = [random.randint(lo, hi) for _ in range(dim)]
            q = [random.randint(lo, hi) for _ in range(dim)]
            ref = sum(a * b for a, b in zip(d, q))
            got = packed_dot(pack_planes(d, bits, dim), pack_planes(q, bits, dim), bits)
            assert got == ref, (bits, dim, got, ref)


def check_extremes():
    # worst case magnitude: 512 * 128 * 128 = 2^23 -- i64 headroom is huge
    for d_v, q_v in [(-128, -128), (-128, 127), (127, 127), (-128, 1), (127, -1)]:
        dim = 512
        d, q = [d_v] * dim, [q_v] * dim
        ref = sum(a * b for a, b in zip(d, q))
        got = packed_dot(pack_planes(d, 8, dim), pack_planes(q, 8, dim), 8)
        assert got == ref, (d_v, q_v, got, ref)
        assert abs(ref) <= 512 * 128 * 128 < 2**63
    # exhaustive single-element i8 x i8: every pair, both INT8 and (range-
    # clamped) INT4
    for a in range(-128, 128):
        for b in range(-128, 128):
            got = packed_dot(pack_planes([a], 8, 1), pack_planes([b], 8, 1), 8)
            assert got == a * b, (a, b, got)
    for a in range(-8, 8):
        for b in range(-8, 8):
            got = packed_dot(pack_planes([a], 4, 1), pack_planes([b], 4, 1), 4)
            assert got == a * b, (a, b, got)


def check_flip_corrections(bits, lo, hi, trials):
    dim = 96
    for _ in range(trials):
        d = [random.randint(lo, hi) for _ in range(dim)]
        q = [random.randint(lo, hi) for _ in range(dim)]
        planes = pack_planes(d, bits, dim)
        qp = pack_planes(q, bits, dim)
        base = packed_dot(planes, qp, bits)
        e = random.randrange(dim)
        b = random.randrange(bits)
        was_one = bool((planes[b] >> e) & 1)
        planes[b] ^= 1 << e  # the physical flip
        flipped = packed_dot(planes, qp, bits)
        value_delta = -bit_weight(b, bits) if was_one else bit_weight(b, bits)
        assert flipped - base == value_delta * q[e], (bits, e, b, was_one)


def main():
    check_identity(8, -128, 127, dims=[1, 60, 64, 65, 128, 200, 512], trials=40)
    check_identity(4, -8, 7, dims=[1, 60, 64, 65, 128, 200, 512], trials=40)
    check_extremes()
    check_flip_corrections(8, -128, 127, trials=400)
    check_flip_corrections(4, -8, 7, trials=400)
    print("audit_packed_kernel: all identities hold")


if __name__ == "__main__":
    main()
