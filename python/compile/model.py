"""L2 — the DIRC-RAG retrieval compute graphs (build-time JAX).

These are the functions AOT-lowered to HLO text by :mod:`compile.aot` and
executed from the Rust runtime via PJRT. Python never runs at serve time.

Graphs:

  * ``mips_graph``          — integer inner-product scores over a document
                              block (dot fast path or bit-serial DIRC path)
  * ``cosine_topk_graph``   — cosine similarity + fused ``lax.top_k`` (the
                              per-core local top-k of Fig. 3a)
  * ``mips_topk_graph``     — MIPS + fused top-k
  * ``embed_graph``         — the synthetic "embedding model": a 2-layer
                              MLP over hashed bag-of-words features with
                              L2-normalised 512-d output. Stands in for
                              all-MiniLM-L6-v2 (see DESIGN.md substitutions).
                              Weights are runtime *inputs* (uploaded once by
                              the Rust runtime from ``embed_weights.bin``):
                              baking them as HLO constants does not survive
                              the text interchange, which elides large
                              literals as ``{...}``.

All quantized tensors cross the PJRT boundary as int32 (the ``xla`` crate
exposes i32/i64/u32/u64/f32/f64 literals only); values stay within the
INT8/INT4 range.

Top-k note: ``lax.top_k`` lowers to the new ``topk(..., largest=true)``
HLO instruction, which xla_extension 0.5.1's text parser rejects; the
fused top-k graphs therefore use a stable ``lax.sort_key_val`` + slice,
which lowers to the classic ``sort`` instruction (and preserves the
deterministic lowest-index tie-break the Rust comparator uses).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

from .kernels import bitserial as kern

# ---------------------------------------------------------------------------
# Embedding model constants (the synthetic all-MiniLM stand-in).
# ---------------------------------------------------------------------------

EMBED_VOCAB = 2048    # hashed bag-of-words buckets
EMBED_HIDDEN = 256
EMBED_DIM = 512       # matches the paper's SBERT dimension
EMBED_SEED = 0x51C0FFEE


def embed_weights() -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic MLP weights (written to artifacts/embed_weights.bin)."""
    rs = np.random.RandomState(EMBED_SEED & 0x7FFFFFFF)
    scale1 = 1.0 / np.sqrt(EMBED_VOCAB)
    scale2 = 1.0 / np.sqrt(EMBED_HIDDEN)
    w1 = rs.normal(0.0, scale1, size=(EMBED_VOCAB, EMBED_HIDDEN)).astype(np.float32)
    b1 = np.zeros((EMBED_HIDDEN,), np.float32)
    w2 = rs.normal(0.0, scale2, size=(EMBED_HIDDEN, EMBED_DIM)).astype(np.float32)
    b2 = np.zeros((EMBED_DIM,), np.float32)
    return w1, b1, w2, b2


def embed_graph(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
                w2: jnp.ndarray, b2: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Hashed-BoW -> L2-normalised embedding. x: [B, EMBED_VOCAB] f32."""
    h = jnp.tanh(x @ w1 + b1)
    e = h @ w2 + b2
    norm = jnp.sqrt(jnp.sum(e * e, axis=1, keepdims=True))
    return (e / jnp.maximum(norm, 1e-12),)


# ---------------------------------------------------------------------------
# Retrieval graphs.
# ---------------------------------------------------------------------------


def mips_graph(d: jnp.ndarray, q: jnp.ndarray, *, bits: int = 8,
               bitserial: bool = False, tile_n: int = 128) -> tuple[jnp.ndarray]:
    """Integer MIPS scores for one document block. Returns ([N] i32,)."""
    if bitserial:
        scores = kern.bitserial_scores(d, q, bits=bits, tile_n=tile_n)
    else:
        scores = kern.dot_scores(d, q, tile_n=tile_n)
    return (scores,)


def mips_plain_graph(d: jnp.ndarray, q: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Serving fast path: one fused XLA dot over the whole block, no
    Pallas grid loop. Functionally identical to ``mips_graph``; exists
    because the interpret-mode pallas_call lowers to a serial while-loop
    over grid steps that XLA:CPU cannot parallelise — the plain dot is
    ~an order of magnitude faster per block (see EXPERIMENTS.md §Perf)."""
    scores = jnp.dot(d, q, preferred_element_type=jnp.int32)
    return (scores,)


def _topk_sorted(scores: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k via stable sort (see module docstring): descending scores,
    lowest index wins ties — matching the Rust TopK comparator."""
    n = scores.shape[0]
    idx = lax.iota(jnp.int32, n)
    sorted_neg, sorted_idx = lax.sort_key_val(-scores, idx, is_stable=True)
    return -sorted_neg[:k], sorted_idx[:k]


def mips_topk_graph(d: jnp.ndarray, q: jnp.ndarray, *, k: int,
                    tile_n: int = 128) -> tuple[jnp.ndarray, jnp.ndarray]:
    """MIPS scores + fused local top-k. Returns (vals f32[k], idx i32[k]).

    Values are emitted as f32 so the Rust-side global comparator consumes a
    single score type for both metrics.
    """
    scores = kern.dot_scores(d, q, tile_n=tile_n).astype(jnp.float32)
    vals, idx = _topk_sorted(scores, k)
    return (vals, idx.astype(jnp.int32))


def cosine_topk_graph(d: jnp.ndarray, q: jnp.ndarray, d_norm: jnp.ndarray,
                      q_norm: jnp.ndarray, *, k: int, tile_n: int = 128
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Cosine similarity + fused local top-k.

    d_norm: [N] f32 document embedding norms (from the core's ReRAM buffer)
    q_norm: [] f32 query norm (from the chip's norm unit)
    Returns (vals f32[k], idx i32[k]).
    """
    ip = kern.dot_scores(d, q, tile_n=tile_n).astype(jnp.float32)
    denom = jnp.maximum(d_norm * q_norm, 1e-12)
    scores = ip / denom
    vals, idx = _topk_sorted(scores, k)
    return (vals, idx.astype(jnp.int32))


def cosine_scores_graph(d: jnp.ndarray, q: jnp.ndarray, d_norm: jnp.ndarray,
                        q_norm: jnp.ndarray, *, tile_n: int = 128
                        ) -> tuple[jnp.ndarray]:
    """Cosine similarity scores without top-k (full score vector out)."""
    ip = kern.dot_scores(d, q, tile_n=tile_n).astype(jnp.float32)
    denom = jnp.maximum(d_norm * q_norm, 1e-12)
    return (ip / denom,)
