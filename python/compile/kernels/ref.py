"""Pure-jnp correctness oracles for the DIRC bit-serial MAC kernel.

These are the ground-truth references the Pallas kernels (and, transitively,
the Rust hardware simulator) are validated against. Everything here is
straight-line jnp with no Pallas, no custom lowering.

The DIRC column computes, per document embedding ``d`` and query ``q``
(both two's-complement INT``B``):

    score(d, q) = sum_i d_i * q_i        (exact integer inner product)

via a bit-serial expansion:

    d_i = -2^(B-1) * d_i[B-1] + sum_{b<B-1} 2^b * d_i[b]
    q_i likewise,
    score = sum_{db, qb} w(db) * w(qb) * sum_i d_i[db] & q_i[qb]

where the inner sum over ``i`` is the macro's 128-input carry-save adder
and the outer double loop is the query-stationary bit schedule. The
bit-serial expansion is *exactly* equal to the integer dot product, so the
oracle is simply an int32 matmul.
"""

from __future__ import annotations

import jax.numpy as jnp


def int_range(bits: int) -> tuple[int, int]:
    """Inclusive [lo, hi] representable range of a signed ``bits``-bit int."""
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def mips_scores(d: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact integer inner-product scores.

    d: [N, dim] int32 (values within the quantized INT4/INT8 range)
    q: [dim]    int32
    returns: [N] int32
    """
    return jnp.dot(d.astype(jnp.int32), q.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def cosine_scores(d: jnp.ndarray, q: jnp.ndarray,
                  d_norm: jnp.ndarray, q_norm: jnp.ndarray) -> jnp.ndarray:
    """Cosine similarity from integer dot products and pre-computed norms.

    d_norm: [N] f32 — L2 norms of the (de-quantized) document embeddings
    q_norm: scalar f32 — L2 norm of the query embedding
    """
    ip = mips_scores(d, q).astype(jnp.float32)
    denom = jnp.maximum(d_norm * q_norm, 1e-12)
    return ip / denom


def bit_decompose(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Two's-complement bit-plane decomposition.

    x: int32 array with values in the signed ``bits``-bit range.
    returns: [bits, *x.shape] int32 of {0,1} planes; plane b is bit b.

    Works on negative values because the low ``bits`` bits of the int32
    two's-complement pattern equal the INT``bits`` pattern.
    """
    planes = [(x >> b) & 1 for b in range(bits)]
    return jnp.stack(planes, axis=0)


def bit_weight(b: int, bits: int) -> int:
    """Positional weight of bit ``b`` in a signed ``bits``-bit integer."""
    return -(1 << b) if b == bits - 1 else (1 << b)


def bitserial_scores_ref(d: jnp.ndarray, q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Bit-serial expansion of the integer dot product, mirroring the DIRC
    query-stationary schedule (D-bit outer loop, Q-bit inner loop) but in
    plain jnp. Must equal :func:`mips_scores` exactly.
    """
    d = d.astype(jnp.int32)
    q = q.astype(jnp.int32)
    acc = jnp.zeros((d.shape[0],), jnp.int32)
    for db in range(bits):
        d_plane = (d >> db) & 1                       # [N, dim]
        for qb in range(bits):
            q_plane = (q >> qb) & 1                   # [dim]
            # NOR-gate multiplier array == AND of the two bit planes.
            partial = jnp.sum(d_plane * q_plane, axis=1)  # 128-input CSA
            acc = acc + partial * (bit_weight(db, bits) * bit_weight(qb, bits))
    return acc
