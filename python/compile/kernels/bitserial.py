"""L1 — the DIRC column digital MAC as a Pallas kernel.

The paper's compute hot-spot is the DIRC macro: a 128x128 plane of
ReRAM-SRAM coupled cells feeding, per column, 128 NOR-gate bit multipliers,
a 128-input carry-save adder and a shift accumulator, driven by the
bit-level query-stationary (QS) schedule of Fig. 4:

    for D_bit in 0..B:          # document bit-plane sensed into SRAM
        for Q_bit in 0..B:      # query bit broadcast from input registers
            column_psum = CSA_128(d_plane & q_plane)
            acc += column_psum << (D_bit + Q_bit)   # (sign-corrected)

Hardware adaptation (custom 40nm digital CIM -> TPU-style Pallas):

  * the 128x128 SRAM compute plane -> a (TILE_N, dim) VMEM block chosen by
    ``BlockSpec``; grid steps walk document tiles, which is the role the 16
    parallel macros play on-chip;
  * NOR multiplier + 128-input CSA   -> elementwise AND of bit planes and a
    lane-axis ``jnp.sum`` (XLA's reduction tree is the CSA);
  * the bit-serial accumulator       -> an unrolled double loop over the
    B*B bit pairs carrying an int32 accumulator, with two's-complement
    positional weights (bit B-1 weighs -2^(B-1)).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime. Real-TPU VMEM/MXU characteristics are
estimated in DESIGN.md §Perf instead of measured.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import bit_weight

# Default document-tile height. 128 matches the macro's column count so one
# grid step corresponds to one macro-sized slab of documents.
DEFAULT_TILE_N = 128


def _bitserial_kernel(d_ref, q_ref, o_ref, *, bits: int):
    """Pallas kernel body: bit-serial integer dot of a document tile.

    d_ref: [TILE_N, dim] int32 block (two's-complement INT``bits`` values)
    q_ref: [1, dim] int32 (query row, replicated to every grid step)
    o_ref: [TILE_N] int32 scores
    """
    d = d_ref[...]
    q = q_ref[...]
    acc = jnp.zeros((d.shape[0],), jnp.int32)
    # QS schedule: D bit-plane outer (one ReRAM sense each), Q bit inner
    # (one input-register broadcast each). Unrolled: `bits` is static.
    for db in range(bits):
        d_plane = (d >> db) & 1
        w_d = bit_weight(db, bits)
        for qb in range(bits):
            q_plane = (q >> qb) & 1
            w_q = bit_weight(qb, bits)
            # NOR-gate bit-multiplier array == AND of bit planes.
            prod = d_plane * q_plane                   # [TILE_N, dim] of {0,1}
            psum = jnp.sum(prod, axis=1)               # 128-input CSA
            acc = acc + psum * (w_d * w_q)             # shift accumulator
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bits", "tile_n"))
def bitserial_scores(d: jnp.ndarray, q: jnp.ndarray, *, bits: int = 8,
                     tile_n: int = DEFAULT_TILE_N) -> jnp.ndarray:
    """Integer MIPS scores via the bit-serial Pallas kernel.

    d: [N, dim] int32, values in the signed ``bits``-bit range
    q: [dim]    int32, same range
    returns: [N] int32 exact inner products

    N must be divisible by ``tile_n`` (the library pads on the Rust side;
    the AOT artifacts are emitted for fixed padded shapes).
    """
    n, dim = d.shape
    if n % tile_n != 0:
        raise ValueError(f"N={n} not divisible by tile_n={tile_n}")
    q2 = q.reshape(1, dim)
    grid = (n // tile_n,)
    return pl.pallas_call(
        functools.partial(_bitserial_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, dim), lambda i: (i, 0)),
            pl.BlockSpec((1, dim), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(d, q2)


def _dot_kernel(d_ref, q_ref, o_ref):
    """Fast-path kernel: plain int32 contraction of a document tile.

    Functionally identical to the bit-serial kernel (the bit expansion is
    exact); used for the serving fast path where the per-bit structure is
    not being exercised. On a real TPU this is the MXU variant; the
    bit-serial kernel is the VPU/bitwise variant.
    """
    d = d_ref[...]
    q = q_ref[...]
    o_ref[...] = jax.lax.dot_general(
        d, q[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("tile_n",))
def dot_scores(d: jnp.ndarray, q: jnp.ndarray, *,
               tile_n: int = DEFAULT_TILE_N) -> jnp.ndarray:
    """Integer MIPS scores via the dot-based Pallas fast path."""
    n, dim = d.shape
    if n % tile_n != 0:
        raise ValueError(f"N={n} not divisible by tile_n={tile_n}")
    q2 = q.reshape(1, dim)
    grid = (n // tile_n,)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, dim), lambda i: (i, 0)),
            pl.BlockSpec((1, dim), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(d, q2)


def vmem_bytes_per_step(tile_n: int, dim: int) -> int:
    """Estimated VMEM residency of one grid step (documented in DESIGN.md).

    One i32 document tile + the i32 query row + the i32 accumulator and two
    transient bit planes. Used to size TILE_N so a real-TPU port stays well
    under the ~16 MiB VMEM budget.
    """
    doc_tile = tile_n * dim * 4
    query = dim * 4
    acc = tile_n * 4
    transients = 2 * tile_n * dim * 4
    return doc_tile + query + acc + transients
