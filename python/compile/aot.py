"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Emits ``<name>.hlo.txt`` per artifact plus ``manifest.json`` describing
input/output shapes and metadata; the Rust runtime
(``rust/src/runtime/registry.rs``) reads the manifest, compiles each module
on the PJRT CPU client once, and executes from the serve path.

HLO **text** is the interchange format, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``;
the Rust side unwraps with ``to_tuple1``/``to_tupleN``.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Artifact catalogue.
#
# Block shapes mirror the hardware geometry: one DIRC-RAG core holds 2 Mb of
# NVM = 256 Kb usable INT8 values / dim. With dim=512 a core holds 512 INT8
# embeddings per macro-column-group; the serving blocks below are the
# per-core slabs the coordinator dispatches (padded to the block size).
# Small 128x64 shapes are fast-compile variants for tests.
# ---------------------------------------------------------------------------

ARTIFACTS: list[dict] = []


def _art(name: str, fn, specs: list[jax.ShapeDtypeStruct], outputs: list[dict],
         **meta) -> None:
    ARTIFACTS.append({
        "name": name,
        "fn": fn,
        "specs": specs,
        "outputs": outputs,
        "meta": meta,
    })


def _i32(*shape: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _build_catalogue() -> None:
    # --- MIPS score blocks (dot fast path) ---
    for n, dim, tile in [(128, 64, 64), (1024, 512, 128), (4096, 512, 128)]:
        _art(
            f"mips_dot_int8_{n}x{dim}",
            functools.partial(model.mips_graph, bitserial=False, tile_n=tile),
            [_i32(n, dim), _i32(dim)],
            [{"dtype": "i32", "shape": [n]}],
            kind="mips", bits=8, n=n, dim=dim, tile_n=tile, path="dot",
        )

    # --- Serving fast-path blocks: one fused dot per block (see
    #     model.mips_plain_graph docstring) ---
    for n, dim in [(1024, 512), (2048, 512), (4096, 512), (8192, 512),
                   (2048, 128), (8192, 128), (4096, 1024), (128, 64)]:
        _art(
            f"mips_plain_int8_{n}x{dim}",
            model.mips_plain_graph,
            [_i32(n, dim), _i32(dim)],
            [{"dtype": "i32", "shape": [n]}],
            kind="mips_plain", bits=8, n=n, dim=dim, path="plain",
        )

    # --- Bit-serial DIRC-path blocks (structural fidelity) ---
    for bits, n, dim, tile in [(8, 128, 64, 64), (8, 1024, 512, 128),
                               (4, 128, 64, 64), (4, 1024, 512, 128)]:
        _art(
            f"mips_bitserial_int{bits}_{n}x{dim}",
            functools.partial(model.mips_graph, bits=bits, bitserial=True,
                              tile_n=tile),
            [_i32(n, dim), _i32(dim)],
            [{"dtype": "i32", "shape": [n]}],
            kind="mips", bits=bits, n=n, dim=dim, tile_n=tile, path="bitserial",
        )

    # --- Fused score + local-top-k blocks (the per-core hot path) ---
    for n, dim, tile, k in [(128, 64, 64, 5), (1024, 512, 128, 10),
                            (4096, 512, 128, 10)]:
        _art(
            f"mips_topk_int8_{n}x{dim}_k{k}",
            functools.partial(model.mips_topk_graph, k=k, tile_n=tile),
            [_i32(n, dim), _i32(dim)],
            [{"dtype": "f32", "shape": [k]}, {"dtype": "i32", "shape": [k]}],
            kind="mips_topk", bits=8, n=n, dim=dim, tile_n=tile, k=k,
        )
        _art(
            f"cosine_topk_int8_{n}x{dim}_k{k}",
            functools.partial(model.cosine_topk_graph, k=k, tile_n=tile),
            [_i32(n, dim), _i32(dim), _f32(n), _f32()],
            [{"dtype": "f32", "shape": [k]}, {"dtype": "i32", "shape": [k]}],
            kind="cosine_topk", bits=8, n=n, dim=dim, tile_n=tile, k=k,
        )

    # --- Full cosine score vector (for evaluation sweeps) ---
    for n, dim, tile in [(1024, 512, 128)]:
        _art(
            f"cosine_scores_int8_{n}x{dim}",
            functools.partial(model.cosine_scores_graph, tile_n=tile),
            [_i32(n, dim), _i32(dim), _f32(n), _f32()],
            [{"dtype": "f32", "shape": [n]}],
            kind="cosine", bits=8, n=n, dim=dim, tile_n=tile,
        )

    # --- Embedding model (synthetic all-MiniLM stand-in) ---
    # Weights are inputs (x, w1, b1, w2, b2); aot main() writes the actual
    # weight values to embed_weights.bin for the Rust runtime.
    v, h, d = model.EMBED_VOCAB, model.EMBED_HIDDEN, model.EMBED_DIM
    for batch in (1, 32):
        _art(
            f"embed_mlp_b{batch}",
            model.embed_graph,
            [_f32(batch, v), _f32(v, h), _f32(h), _f32(h, d), _f32(d)],
            [{"dtype": "f32", "shape": [batch, d]}],
            kind="embed", batch=batch, vocab=v, hidden=h, dim=d,
            weights_file="embed_weights.bin",
        )


# ---------------------------------------------------------------------------
# Lowering.
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(art: dict, outdir: str) -> dict:
    lowered = jax.jit(art["fn"]).lower(*art["specs"])
    text = to_hlo_text(lowered)
    fname = f"{art['name']}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    entry = {
        "name": art["name"],
        "file": fname,
        "inputs": [
            {"dtype": str(s.dtype), "shape": list(s.shape)} for s in art["specs"]
        ],
        "outputs": art["outputs"],
        "meta": art["meta"],
    }
    return entry


def main() -> None:
    parser = argparse.ArgumentParser(description="DIRC-RAG AOT artifact builder")
    parser.add_argument("--out", default="../artifacts",
                        help="output directory for HLO text artifacts")
    parser.add_argument("--only", default=None,
                        help="substring filter on artifact names")
    args = parser.parse_args()

    os.makedirs(args.out, exist_ok=True)

    # Embedder weights sidecar: f32 little-endian, w1 | b1 | w2 | b2 in
    # row-major order (layout recorded in the artifact meta).
    import numpy as np
    w1, b1, w2, b2 = model.embed_weights()
    flat = np.concatenate([w.reshape(-1) for w in (w1, b1, w2, b2)])
    flat.astype("<f4").tofile(os.path.join(args.out, "embed_weights.bin"))
    print(f"  embed_weights.bin ({flat.nbytes / 1024:.1f} KiB)")

    _build_catalogue()
    manifest = []
    for art in ARTIFACTS:
        if args.only and args.only not in art["name"]:
            continue
        entry = lower_artifact(art, args.out)
        size = os.path.getsize(os.path.join(args.out, entry["file"]))
        print(f"  {entry['name']:44s} -> {entry['file']} ({size/1024:.1f} KiB)")
        manifest.append(entry)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"version": 1, "artifacts": manifest}, f, indent=2)
    print(f"wrote {len(manifest)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
