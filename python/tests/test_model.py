"""L2 graph correctness: retrieval graphs + embedder shapes and semantics."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def _quantize_sym(x: np.ndarray, bits: int) -> np.ndarray:
    """Symmetric per-tensor quantizer (matches rust/src/retrieval/quant.rs)."""
    lo, hi = ref.int_range(bits)
    scale = np.max(np.abs(x)) / hi if np.max(np.abs(x)) > 0 else 1.0
    return np.clip(np.round(x / scale), lo, hi).astype(np.int32)


def test_mips_topk_graph_selects_best():
    rng = np.random.default_rng(0)
    n, dim, k = 256, 64, 5
    d = rng.integers(-128, 128, size=(n, dim)).astype(np.int32)
    q = rng.integers(-128, 128, size=(dim,)).astype(np.int32)
    vals, idx = model.mips_topk_graph(jnp.asarray(d), jnp.asarray(q),
                                      k=k, tile_n=64)
    scores = d.astype(np.int64) @ q.astype(np.int64)
    want_idx = np.argsort(-scores, kind="stable")[:k]
    np.testing.assert_array_equal(np.sort(np.asarray(idx)), np.sort(want_idx))
    np.testing.assert_allclose(np.asarray(vals),
                               scores[np.asarray(idx)].astype(np.float32))


def test_cosine_topk_graph_matches_fp_cosine_ranking():
    """INT8-quantized cosine top-k ranks ~like FP cosine on separable data."""
    rng = np.random.default_rng(1)
    n, dim, k = 256, 64, 3
    base = rng.normal(size=(n, dim)).astype(np.float32)
    base /= np.linalg.norm(base, axis=1, keepdims=True)
    qf = base[17] + 0.05 * rng.normal(size=(dim,)).astype(np.float32)

    d_q = np.stack([_quantize_sym(row, 8) for row in base])
    q_q = _quantize_sym(qf, 8)
    d_norm = np.linalg.norm(d_q.astype(np.float32), axis=1)
    q_norm = np.float32(np.linalg.norm(q_q.astype(np.float32)))

    vals, idx = model.cosine_topk_graph(
        jnp.asarray(d_q), jnp.asarray(q_q), jnp.asarray(d_norm),
        jnp.asarray(q_norm), k=k, tile_n=64)
    assert int(np.asarray(idx)[0]) == 17
    v = np.asarray(vals)
    assert np.all(v[:-1] >= v[1:])          # sorted descending
    assert v[0] <= 1.0 + 1e-5               # cosine bound


def test_cosine_scores_graph_matches_ref():
    rng = np.random.default_rng(2)
    n, dim = 128, 64
    d = rng.integers(-128, 128, size=(n, dim)).astype(np.int32)
    q = rng.integers(-128, 128, size=(dim,)).astype(np.int32)
    d_norm = np.linalg.norm(d.astype(np.float32), axis=1)
    q_norm = np.float32(np.linalg.norm(q.astype(np.float32)))
    (got,) = model.cosine_scores_graph(
        jnp.asarray(d), jnp.asarray(q), jnp.asarray(d_norm),
        jnp.asarray(q_norm), tile_n=64)
    want = ref.cosine_scores(jnp.asarray(d), jnp.asarray(q),
                             jnp.asarray(d_norm), jnp.asarray(q_norm))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def _embed(x: np.ndarray) -> np.ndarray:
    w1, b1, w2, b2 = model.embed_weights()
    (e,) = model.embed_graph(*(jnp.asarray(a) for a in (x, w1, b1, w2, b2)))
    return np.asarray(e)


def test_embed_graph_normalised_and_deterministic():
    rng = np.random.default_rng(3)
    x = rng.random((4, model.EMBED_VOCAB)).astype(np.float32)
    e1, e2 = _embed(x), _embed(x)
    assert e1.shape == (4, model.EMBED_DIM)
    np.testing.assert_array_equal(e1, e2)
    np.testing.assert_allclose(np.linalg.norm(e1, axis=1), 1.0, rtol=1e-5)


def test_embed_graph_separates_inputs():
    """Different BoW inputs map to distinguishable embeddings."""
    x = np.zeros((2, model.EMBED_VOCAB), np.float32)
    x[0, :16] = 1.0
    x[1, 16:32] = 1.0
    e = _embed(x)
    cos = float(e[0] @ e[1])
    assert cos < 0.99


def test_embed_weights_deterministic():
    a = model.embed_weights()
    b = model.embed_weights()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_topk_sorted_matches_lax_topk():
    rng = np.random.default_rng(5)
    scores = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    vals, idx = model._topk_sorted(scores, 10)
    import jax.lax as lax
    wv, wi = lax.top_k(scores, 10)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(wi))


def test_topk_sorted_stable_tie_break():
    scores = jnp.asarray(np.array([1.0, 2.0, 2.0, 2.0, 0.0], np.float32))
    _, idx = model._topk_sorted(scores, 2)
    np.testing.assert_array_equal(np.asarray(idx), [1, 2])


def test_mips_plain_matches_kernel_path():
    rng = np.random.default_rng(7)
    d = rng.integers(-128, 128, size=(256, 64)).astype(np.int32)
    q = rng.integers(-128, 128, size=(64,)).astype(np.int32)
    (plain,) = model.mips_plain_graph(jnp.asarray(d), jnp.asarray(q))
    (kerneled,) = model.mips_graph(jnp.asarray(d), jnp.asarray(q), tile_n=64)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(kerneled))
    want = d.astype(np.int64) @ q.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(plain, np.int64), want)
