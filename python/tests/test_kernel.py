"""L1 kernel correctness: Pallas bit-serial/dot kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute layer: the bit-serial
expansion must be *exactly* the integer inner product for every shape,
bit-width and value pattern. Hypothesis sweeps shapes/dtypes; fixed cases
pin the hardware geometry (128-lane CSA, INT8/INT4 ranges).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitserial as kern
from compile.kernels import ref


def _rand_ints(rng, shape, bits):
    lo, hi = ref.int_range(bits)
    return rng.integers(lo, hi + 1, size=shape, dtype=np.int64).astype(np.int32)


# ---------------------------------------------------------------------------
# Fixed geometry cases.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("n,dim,tile", [(128, 128, 128), (256, 64, 64),
                                        (128, 512, 128)])
def test_bitserial_matches_oracle(bits, n, dim, tile):
    rng = np.random.default_rng(seed=bits * 1000 + n + dim)
    d = _rand_ints(rng, (n, dim), bits)
    q = _rand_ints(rng, (dim,), bits)
    got = kern.bitserial_scores(jnp.asarray(d), jnp.asarray(q),
                                bits=bits, tile_n=tile)
    want = ref.mips_scores(jnp.asarray(d), jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,dim,tile", [(128, 128, 128), (512, 64, 64)])
def test_dot_kernel_matches_oracle(n, dim, tile):
    rng = np.random.default_rng(seed=n * 7 + dim)
    d = _rand_ints(rng, (n, dim), 8)
    q = _rand_ints(rng, (dim,), 8)
    got = kern.dot_scores(jnp.asarray(d), jnp.asarray(q), tile_n=tile)
    want = ref.mips_scores(jnp.asarray(d), jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitserial_ref_equals_dot_ref():
    """The jnp-level bit-serial expansion itself is exact."""
    rng = np.random.default_rng(seed=42)
    for bits in (4, 8):
        d = jnp.asarray(_rand_ints(rng, (64, 96), bits))
        q = jnp.asarray(_rand_ints(rng, (96,), bits))
        np.testing.assert_array_equal(
            np.asarray(ref.bitserial_scores_ref(d, q, bits)),
            np.asarray(ref.mips_scores(d, q)))


def test_extreme_values_int8():
    """Saturating patterns: all -128 x all -128 etc. must not overflow i32."""
    dim = 512
    d = jnp.full((128, dim), -128, jnp.int32)
    q = jnp.full((dim,), -128, jnp.int32)
    got = kern.bitserial_scores(d, q, bits=8, tile_n=128)
    assert int(got[0]) == (-128) * (-128) * dim
    q2 = jnp.full((dim,), 127, jnp.int32)
    got2 = kern.bitserial_scores(d, q2, bits=8, tile_n=128)
    assert int(got2[0]) == (-128) * 127 * dim


def test_bit_decompose_roundtrip():
    rng = np.random.default_rng(seed=3)
    for bits in (4, 8):
        x = jnp.asarray(_rand_ints(rng, (32,), bits))
        planes = ref.bit_decompose(x, bits)
        recon = sum(int(ref.bit_weight(b, bits)) * planes[b] for b in range(bits))
        np.testing.assert_array_equal(np.asarray(recon), np.asarray(x))


def test_tile_mismatch_raises():
    d = jnp.zeros((100, 64), jnp.int32)
    q = jnp.zeros((64,), jnp.int32)
    with pytest.raises(ValueError):
        kern.bitserial_scores(d, q, bits=8, tile_n=64)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, bit-widths, adversarial values.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 3),
    tile=st.sampled_from([8, 16, 64]),
    dim=st.sampled_from([8, 32, 128]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_bitserial_sweep(n_tiles, tile, dim, bits, seed):
    rng = np.random.default_rng(seed)
    n = n_tiles * tile
    d = _rand_ints(rng, (n, dim), bits)
    q = _rand_ints(rng, (dim,), bits)
    got = kern.bitserial_scores(jnp.asarray(d), jnp.asarray(q),
                                bits=bits, tile_n=tile)
    want = np.asarray(d, np.int64) @ np.asarray(q, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


@settings(max_examples=20, deadline=None)
@given(
    dim=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_boundary_values(dim, seed):
    """Vectors drawn only from {min, -1, 0, 1, max}: worst-case bit patterns."""
    rng = np.random.default_rng(seed)
    lo, hi = ref.int_range(8)
    pool = np.array([lo, -1, 0, 1, hi], np.int32)
    d = pool[rng.integers(0, len(pool), size=(64, dim))]
    q = pool[rng.integers(0, len(pool), size=(dim,))]
    got = kern.bitserial_scores(jnp.asarray(d), jnp.asarray(q),
                                bits=8, tile_n=64)
    want = np.asarray(d, np.int64) @ np.asarray(q, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)
