"""AOT path: every catalogued artifact lowers to parseable HLO text and the
manifest is well-formed. Uses a temp dir; the real build is `make artifacts`.
"""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def catalogue():
    aot.ARTIFACTS.clear()
    aot._build_catalogue()
    return list(aot.ARTIFACTS)


def test_catalogue_names_unique(catalogue):
    names = [a["name"] for a in catalogue]
    assert len(names) == len(set(names))
    assert len(names) >= 10


def test_small_artifacts_lower(tmp_path, catalogue):
    """Lower the fast-compile subset and sanity-check the HLO text."""
    small = [a for a in catalogue if "128x64" in a["name"] or "embed_mlp_b1" == a["name"]]
    assert small, "expected small fast-compile artifacts in the catalogue"
    for art in small:
        entry = aot.lower_artifact(art, str(tmp_path))
        path = tmp_path / entry["file"]
        text = path.read_text()
        assert text.startswith("HloModule"), art["name"]
        assert "ROOT" in text
        # return_tuple=True => root computation returns a tuple
        assert "tuple" in text or ")) -> (" in text


def test_manifest_entry_shapes(tmp_path, catalogue):
    art = next(a for a in catalogue if a["name"] == "mips_dot_int8_128x64")
    entry = aot.lower_artifact(art, str(tmp_path))
    assert entry["inputs"][0]["shape"] == [128, 64]
    assert entry["inputs"][1]["shape"] == [64]
    assert entry["outputs"][0] == {"dtype": "i32", "shape": [128]}
    assert entry["meta"]["kind"] == "mips"
    json.dumps(entry)  # JSON-serialisable


def test_built_artifacts_dir_if_present():
    """If `make artifacts` has run, the manifest must index existing files."""
    artdir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(artdir, "manifest.json")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built yet")
    with open(manifest) as f:
        m = json.load(f)
    assert m["version"] == 1
    for entry in m["artifacts"]:
        assert os.path.exists(os.path.join(artdir, entry["file"])), entry["name"]
